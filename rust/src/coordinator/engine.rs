//! The per-matrix sparsification pipeline (§3) behind a session-based
//! serving facade.
//!
//! For every weight matrix, per frame:
//!   score input activation → (apply offline-reorder permutation) →
//!   chunk-select under the (pool-effective) latency model → **plan**
//!   the group's flash reads ([`crate::plan::IoPlanner`]) → **shard**
//!   the plan across the storage pool's members
//!   ([`crate::plan::IoPlanner::shard_into`]) → fan one cross-matrix
//!   command batch out per member
//!   ([`crate::storage::DevicePool::submit_sharded_into`]; a
//!   single-member pool degenerates to the historical
//!   [`crate::storage::FlashDevice::submit`] path) → gather activations
//!   → zero-pad to the compiled budget bucket → execute the stage
//!   artifact. Pool service time is the max over members; per-member
//!   bytes/latency land in the metrics so utilization skew is
//!   observable.
//!
//! A transformer block runs as four such stages (qkv+attention, o-proj,
//! gate/up, down-proj). K/V reuse Q's mask and Up reuses Gate's (they
//! share input activations — Appendix A).
//!
//! ## Sessions, prefetch, and the allocation-free hot path
//!
//! [`Engine`] is built with [`EngineBuilder`] and serves any number of
//! independent [`Session`]s (one per stream; each owns its KV caches,
//! prefetch state, and a [`ScratchArena`]). The engine core is `Sync`:
//! read-mostly state lives behind an `Arc<RwLock<..>>` shared by every
//! session handle, so sessions on different threads serve concurrently
//! over one engine ([`crate::coordinator::Scheduler`] runs a worker pool
//! on exactly this property). Mutable per-stream state is owned by the
//! `Session` itself.
//!
//! The steady-state serving path performs **zero heap allocations**:
//! activations, gather staging, selection scratch, plan/receipt buffers
//! and executor temporaries all come from the session's arena, weights
//! are staged once into pooled buckets and handed to the executor as
//! borrowed [`crate::runtime::TensorView`]s (no clones), and every `*_into` API reuses
//! capacity warmed up on the first call. An allocation-counting
//! integration test enforces this with the default single-threaded
//! kernels; `exec_threads > 1` additionally spawns scoped worker threads
//! per stage, whose transient per-thread state allocates (by design —
//! that mode trades arena purity for kernel parallelism).
//!
//! With prefetch enabled (default), the engine double-buffers I/O against
//! compute: while layer *l*'s stages execute, it plans and submits layer
//! *l+1*'s whole-layer read using the masks the session selected on its
//! *previous* call — streaming frames are temporally correlated, so most
//! of the next selection is already resident when the layer is reached.
//! Prefetched service time is charged only beyond the compute it
//! overlapped; rows the prediction missed are fetched by a small residual
//! plan.
//!
//! ## Asynchronous I/O pipeline
//!
//! With `async_io` on ([`EngineBuilder::async_io`], `NC_ASYNC_IO=1`), the
//! inline double-buffering becomes a real pipeline: up to
//! [`EngineBuilder::io_queue_depth`] whole-layer prefetches are submitted
//! *before* the kernels of the layers they overlap run, and each is
//! awaited only at the moment its layer consumes the weights. Wall-clock
//! pool members route submissions through per-member I/O worker threads
//! behind bounded queues ([`crate::storage::AsyncIoQueue`]), so flash
//! reads genuinely proceed while kernels execute; virtual-clock members
//! ([`crate::storage::SimulatedSsd`]) submit inline and credit the
//! overlap analytically — each stage pays `max(compute, io)` — keeping
//! the latency model exact and deterministic. Either way the pipeline is
//! a pure timing change: outputs and selected chunks are bit-identical
//! to the synchronous path at every queue depth and pool size, and the
//! virtual-time serving path stays allocation-free.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{Context, Result};

use crate::cache::ChunkCache;
use crate::coordinator::arena::ScratchArena;
use crate::coordinator::pipeline::batch::{BatchArena, DecodeRequest};
use crate::coordinator::pipeline::prefill::PrefillPass;
use crate::coordinator::pipeline::stages::{col_importance, full_mask, group_members, rmsnorm};
use crate::coordinator::pipeline::{group_index, SessionState, StageStats};
use crate::coordinator::{HotNeuronCache, KvCache, Metrics, Policy};
use crate::latency::LatencyTable;
use crate::model::{encode_row, DType, MatrixId, MatrixKind, ModelSpec, WeightStore};
use crate::plan::{CoalescePolicy, IoPlanner};
use crate::reorder::{activation_frequency, HotColdReorder};
use crate::runtime::{Manifest, ModelMeta, Tensor, XlaRuntime};
use crate::sparsify::{SelectionMask, Selector};
use crate::storage::{
    dead_member_from_env, AsyncIoQueue, DevicePool, DeviceProfile, FaultConfig, FaultHandle,
    FaultInjector, HedgeConfig, PoolHealthSnapshot, ProfileConfig, Profiler, SimulatedSsd,
    StripeLayout, StripePolicy,
};

/// Builder for [`Engine`] — the only way to construct one.
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    model: String,
    profile: DeviceProfile,
    policy: Policy,
    sparsity: f64,
    seed: u64,
    artifact_dir: PathBuf,
    prefetch: bool,
    coalesce: CoalescePolicy,
    exec_threads: usize,
    devices: usize,
    member_profiles: Option<Vec<DeviceProfile>>,
    stripe_policy: StripePolicy,
    stripe_bytes: Option<usize>,
    replication: usize,
    async_io: bool,
    io_queue_depth: usize,
    backing_dir: Option<PathBuf>,
    cache_mb: usize,
    cache_pricing: bool,
    drift_threshold: Option<f64>,
    dtype: DType,
}

impl EngineBuilder {
    /// Start from a runnable model name ("tiny" | "small" | "base") with
    /// defaults: nano profile, dense policy, prefetch on, contiguous
    /// coalescing, single-threaded kernels, a single-member storage pool
    /// (`NC_DEVICES` overrides the default member count without touching
    /// call sites — CI uses it to run the whole suite sharded),
    /// artifacts in `./artifacts`.
    pub fn new(model: &str) -> Self {
        let devices = std::env::var("NC_DEVICES")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1);
        // `NC_ASYNC_IO=1` flips the default so CI can run the whole test
        // suite through the async pipeline without touching call sites.
        let async_io = std::env::var("NC_ASYNC_IO")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        // `NC_REPLICATION=r` turns on hot-stripe replication suite-wide
        // (chaos CI runs every test against a replicated pool).
        let replication = std::env::var("NC_REPLICATION")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&r| r >= 1)
            .unwrap_or(1);
        // `NC_CACHE_MB=n` gives every engine a shared hot-chunk RAM cache
        // of `n` MiB without touching call sites (CI runs the whole suite
        // with it set; 0 or unset = disabled).
        let cache_mb = std::env::var("NC_CACHE_MB")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .unwrap_or(0);
        // `NC_CACHE_PRICING=1` opts into the paper's §5 semantics where
        // resident rows are repriced (importance zeroed pre-selection) and
        // unioned into the compute set — changes selection, off by default.
        let cache_pricing = std::env::var("NC_CACHE_PRICING")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        // `NC_DRIFT_THRESHOLD=t` arms drift-triggered online re-reordering.
        let drift_threshold = std::env::var("NC_DRIFT_THRESHOLD")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .filter(|t| t.is_finite() && *t > 0.0);
        // `NC_DTYPE=f32|fp16|int8` picks the on-flash storage dtype
        // suite-wide without touching call sites (CI runs the whole test
        // suite at int8; unset or unparsable = f32, the historical image).
        let dtype = std::env::var("NC_DTYPE")
            .ok()
            .and_then(|v| v.parse::<DType>().ok())
            .unwrap_or_default();
        Self {
            model: model.to_string(),
            profile: DeviceProfile::nano(),
            policy: Policy::Dense,
            sparsity: 0.0,
            seed: 42,
            artifact_dir: PathBuf::from("artifacts"),
            prefetch: true,
            coalesce: CoalescePolicy::contiguous(),
            exec_threads: 1,
            devices,
            member_profiles: None,
            stripe_policy: StripePolicy::RoundRobin,
            stripe_bytes: None,
            replication,
            async_io,
            io_queue_depth: 2,
            backing_dir: None,
            cache_mb,
            cache_pricing,
            drift_threshold,
            dtype,
        }
    }

    /// On-flash storage dtype of the weight image (default f32, or
    /// `NC_DTYPE`). Quantized images store per-row scales inline, every
    /// gather dequantizes back into the f32 arenas, and the selection /
    /// planner latency tables are repriced at the encoded row width — so
    /// int8 makes every chunk ~4× cheaper in flash bytes. The f32 path is
    /// bit-identical to builds without the knob; fp16/int8 outputs differ
    /// by bounded quantization error (see DESIGN.md §12).
    pub fn dtype(mut self, dtype: DType) -> Self {
        self.dtype = dtype;
        self
    }

    /// Byte budget (MiB) for the shared cross-session hot-chunk RAM cache
    /// (default 0 = disabled, or `NC_CACHE_MB`). The default cache mode
    /// serves already-selected rows from RAM and never changes selection,
    /// so outputs and selected-chunk sets are bit-identical at any budget.
    pub fn cache_mb(mut self, mb: usize) -> Self {
        self.cache_mb = mb;
        self
    }

    /// Opt into cache-aware pricing (the paper's §5 treatment): resident
    /// rows carry near-zero estimated latency, implemented as zeroing
    /// their importance before selection and unioning them into the
    /// compute set. Changes selection; default off (`NC_CACHE_PRICING`).
    pub fn cache_pricing(mut self, on: bool) -> Self {
        self.cache_pricing = on;
        self
    }

    /// Drift score in [0, 1] past which a cache-maintenance pass triggers
    /// online re-reordering from live traffic (default `None` = never;
    /// `NC_DRIFT_THRESHOLD` overrides).
    pub fn drift_threshold(mut self, threshold: Option<f64>) -> Self {
        self.drift_threshold = threshold.filter(|t| t.is_finite() && *t > 0.0);
        self
    }

    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Fraction of rows *dropped* per matrix, in [0, 1).
    pub fn sparsity(mut self, sparsity: f64) -> Self {
        self.sparsity = sparsity;
        self
    }

    pub fn profile(mut self, profile: DeviceProfile) -> Self {
        self.profile = profile;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn artifacts(mut self, dir: &Path) -> Self {
        self.artifact_dir = dir.to_path_buf();
        self
    }

    /// Enable/disable next-layer prefetch (default on).
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Override how plans coalesce chunk extents into device commands.
    pub fn coalesce(mut self, policy: CoalescePolicy) -> Self {
        self.coalesce = policy;
        self
    }

    /// Worker-thread count for the executor kernels (default 1 = inline).
    /// Outputs are bit-identical at every value.
    pub fn exec_threads(mut self, threads: usize) -> Self {
        self.exec_threads = threads.max(1);
        self
    }

    /// Number of homogeneous storage-pool members (default 1, or
    /// `NC_DEVICES`), each a [`SimulatedSsd`] with the builder's device
    /// profile over its stripe of the flash image. Homogeneous pools of
    /// any size produce bit-identical outputs and identical
    /// selected-chunk sets — only (virtual) service time changes.
    pub fn devices(mut self, n: usize) -> Self {
        self.devices = n.max(1);
        self.member_profiles = None;
        self
    }

    /// Heterogeneous pool: one member per profile (fast + slow flash mix).
    /// Selection utility then prices chunks under the stripe-weighted
    /// blend of the members' `T[s]` tables.
    pub fn device_profiles(mut self, profiles: Vec<DeviceProfile>) -> Self {
        if !profiles.is_empty() {
            self.devices = profiles.len();
            self.member_profiles = Some(profiles);
        }
        self
    }

    /// How stripe blocks are assigned to members (default round-robin;
    /// [`StripePolicy::HotAware`] co-locates each matrix's hottest rows).
    pub fn stripe_policy(mut self, policy: StripePolicy) -> Self {
        self.stripe_policy = policy;
        self
    }

    /// Explicit stripe-unit size in bytes (default: adaptive per matrix,
    /// `⌈rows / (4·devices)⌉` rows).
    pub fn stripe_bytes(mut self, bytes: usize) -> Self {
        self.stripe_bytes = if bytes == 0 { None } else { Some(bytes) };
        self
    }

    /// Hot-stripe replication factor (default 1, or `NC_REPLICATION`):
    /// each matrix's hot head is stored on `r` pool members
    /// ([`StripeLayout::build_replicated`]), so reads route to the
    /// least-loaded holder, hedge around stragglers, and keep serving
    /// replica-covered extents when a member dies. Replicas are
    /// byte-identical — outputs and selections are invariant in `r`.
    /// Clamped to the member count at build time.
    pub fn replication(mut self, r: usize) -> Self {
        self.replication = r.max(1);
        self
    }

    /// Enable the asynchronous I/O pipeline (default off, or
    /// `NC_ASYNC_IO=1`): layer *k+1*'s prefetch is submitted *before*
    /// layer *k*'s kernels run and awaited only when its weights are
    /// consumed. Wall-clock pool members genuinely overlap flash reads
    /// with compute on per-member worker threads; virtual-clock members
    /// are accounted analytically as `max(compute, io)` per stage, so the
    /// latency model stays exact. A pure timing optimization: outputs and
    /// selections are bit-identical with it on or off, at any queue
    /// depth and pool size. Requires prefetch (the default) to have any
    /// effect.
    pub fn async_io(mut self, on: bool) -> Self {
        self.async_io = on;
        self
    }

    /// Bound on in-flight whole-layer prefetches (and on each async I/O
    /// worker's submission queue). Default 2; values are clamped to ≥ 1.
    pub fn io_queue_depth(mut self, depth: usize) -> Self {
        self.io_queue_depth = depth.max(1);
        self
    }

    /// Serve from *real* storage: the flash image is sharded into one
    /// backing file per pool member under `dir` (created if missing,
    /// rewritten on build and on re-calibration) and read through
    /// wall-clock [`crate::storage::RealFileDevice`] members. Selection
    /// still prices chunks with the profiled `T[s]` tables, so outputs
    /// and selections stay bit-identical to the simulated pool. Use a
    /// distinct directory per engine.
    pub fn file_backed(mut self, dir: &Path) -> Self {
        self.backing_dir = Some(dir.to_path_buf());
        self
    }

    /// Build the engine, generating + "flashing" the model weights.
    pub fn build(self) -> Result<Engine> {
        let runtime = XlaRuntime::open(&self.artifact_dir)?;
        let meta = runtime
            .manifest
            .model(&self.model)
            .with_context(|| format!("model {} not in manifest", self.model))?
            .clone();
        let spec = ModelSpec::by_name(&self.model)
            .with_context(|| format!("unknown model {}", self.model))?;
        anyhow::ensure!(spec.runnable, "engine needs a runnable model");
        anyhow::ensure!(
            spec.d == meta.d && spec.h == meta.h && spec.layers == meta.layers,
            "rust spec / python manifest dimension mismatch"
        );
        let store = WeightStore::with_dtype(spec.clone(), false, self.seed, self.dtype);
        let member_profiles: Vec<DeviceProfile> = match &self.member_profiles {
            Some(v) if !v.is_empty() => v.clone(),
            _ => vec![self.profile.clone(); self.devices.max(1)],
        };
        let n_dev = member_profiles.len();

        // Profile T[s] once per *distinct* member profile against an
        // unbounded twin (the analytical model is capacity-independent).
        // Sharing one probe seed per profile keeps homogeneous pools of
        // any size on the same table — and therefore on the same
        // selections — as a single device.
        let mut distinct: Vec<(String, LatencyTable)> = Vec::new();
        for p in &member_profiles {
            if distinct.iter().any(|(name, _)| *name == p.name) {
                continue;
            }
            let probe = SimulatedSsd::timing_only(p.clone(), 1 << 40, self.seed ^ 0xBEEF);
            let sat = p.saturation_bytes(0.99);
            let t = Profiler::new(&probe, ProfileConfig::coarse(sat, 1024)).build_table()?;
            distinct.push((p.name.clone(), t));
        }
        let member_tables: Vec<LatencyTable> = member_profiles
            .iter()
            .map(|p| {
                distinct
                    .iter()
                    .find(|(name, _)| *name == p.name)
                    .expect("profiled above")
                    .1
                    .clone()
            })
            .collect();

        // Stripe the flat weight space across the members and blend the
        // member tables into the pool-effective T[s] that selection
        // utility prices chunks with (homogeneous pools reuse the single
        // member table verbatim).
        let stripe = StripeLayout::build_replicated(
            &store.layout,
            n_dev,
            self.stripe_policy,
            self.stripe_bytes,
            self.replication,
        );
        let table = if distinct.len() == 1 {
            distinct[0].1.clone()
        } else {
            LatencyTable::blended(&member_tables, stripe.device_bytes())
        };
        let mut pool = build_pool(
            &member_profiles,
            stripe,
            &store.build_image(),
            self.seed ^ 0xD1CE,
            self.backing_dir.as_deref(),
        )?
        .with_tables(member_tables.clone())
        .with_hedge(HedgeConfig::from_env());
        apply_env_faults(&mut pool);
        // Wall-clock members get per-member async I/O workers; an
        // all-virtual pool needs none (overlap is credited analytically).
        // Workers share the pool-health handle so their retries and
        // dead-member marks land on the same counters as inline reads.
        let async_pipe = (self.async_io && !pool.is_virtual_time()).then(|| {
            AsyncIoQueue::start_with_health(
                pool.member_arcs(),
                self.io_queue_depth,
                Some(pool.health()),
            )
        });
        let dev_io_names: Vec<String> = (0..n_dev).map(|m| format!("io.dev{m}")).collect();

        // Pre-key the table for every scored row size and pre-render every
        // artifact name; both lookups are on the per-stage hot path and
        // must not allocate there. Keys come from the *layout* (encoded)
        // row width, not the spec's logical f32 width — this is the
        // repricing step: a quantized image makes every chunk cheaper in
        // the utility denominator exactly as its flash bytes shrink.
        let mut keyed_tables: HashMap<usize, LatencyTable> = HashMap::new();
        for kind in MatrixKind::SCORED {
            let row_bytes = store.layout.row_bytes(MatrixId::new(0, kind));
            keyed_tables
                .entry(row_bytes)
                .or_insert_with(|| table.with_row_bytes(row_bytes));
        }
        let mut artifact_names: HashMap<(&'static str, bool, usize), String> = HashMap::new();
        let mut buckets: Vec<usize> = meta
            .d_buckets
            .iter()
            .chain(meta.h_buckets.iter())
            .copied()
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        for &bucket in &buckets {
            for tt in [meta.t, 1] {
                for base in ["qkv", "gateup", "projres"] {
                    let kind = match (base, tt) {
                        ("qkv", 1) => "qkv_decode".to_string(),
                        ("qkv", _) => "qkv_append".to_string(),
                        (b, 1) => format!("{b}_dec"),
                        (b, _) => b.to_string(),
                    };
                    artifact_names.insert(
                        (base, tt == 1, bucket),
                        Manifest::artifact_name(&kind, &self.model, bucket),
                    );
                }
            }
        }

        let selector = self.policy.selector();
        // Shared cross-session hot-chunk RAM cache: one shard per
        // (layer, scored group), budget split proportionally to each
        // shard's flash footprint, populated by maintenance passes from
        // live selection frequency (seeded by calibration priors).
        let chunk_cache = (self.cache_mb > 0).then(|| {
            Arc::new(ChunkCache::new(
                (self.cache_mb as u64) << 20,
                self.cache_pricing,
                MatrixKind::SCORED.len(),
                cache_shard_specs(&spec, &store),
                store.dtype(),
            ))
        });
        // Pre-rendered per-dtype I/O counter name: the metrics folds bump
        // it on the hot path and must not format strings there.
        let io_dtype_bytes = match store.dtype() {
            DType::F32 => "io.bytes_f32",
            DType::F16 => "io.bytes_fp16",
            DType::Int8 => "io.bytes_int8",
        };
        let core = EngineCore {
            model: self.model,
            policy: self.policy,
            sparsity: self.sparsity,
            seed: self.seed,
            prefetch: self.prefetch,
            async_io: self.async_io,
            io_queue_depth: self.io_queue_depth,
            async_pipe,
            backing_dir: self.backing_dir,
            exec_threads: self.exec_threads,
            runtime,
            meta,
            spec,
            store,
            pool,
            member_profiles,
            member_tables,
            stripe_policy: self.stripe_policy,
            stripe_bytes: self.stripe_bytes,
            replication: self.replication,
            dev_io_names,
            io_dtype_bytes,
            table,
            keyed_tables,
            artifact_names,
            planner: IoPlanner::new(self.coalesce),
            selector,
            neuron_cache: None,
            chunk_cache,
            drift_threshold: self.drift_threshold,
            cache_ticks: AtomicU64::new(0),
            metrics: Mutex::new(Metrics::new()),
            batch_arenas: Mutex::new(Vec::new()),
            epoch: 0,
        };
        Ok(Engine {
            core: Arc::new(RwLock::new(core)),
        })
    }
}

/// The serving engine facade. `Clone` + `Send` + `Sync`: handles are
/// cheap `Arc` clones and sessions opened from any of them share the
/// flash device, weight store, latency table and planner. Serving takes
/// the core read lock; only re-calibration takes the write lock.
#[derive(Clone)]
pub struct Engine {
    core: Arc<RwLock<EngineCore>>,
}

impl Engine {
    pub fn builder(model: &str) -> EngineBuilder {
        EngineBuilder::new(model)
    }

    /// Open an independent serving session (own KV caches, own prefetch
    /// state, own scratch arena). Sessions must not outlive calibration
    /// epochs silently — they detect re-calibration and reset themselves.
    pub fn new_session(&self) -> Session {
        let core = self.core.read().unwrap();
        let mut state = SessionState::new(&core.spec, core.epoch);
        let mut scratch = ScratchArena::default();
        core.reserve_session_buffers(&mut state, &mut scratch);
        drop(core);
        Session {
            core: self.core.clone(),
            inner: Mutex::new(SessionInner {
                state,
                scratch,
                pass: None,
            }),
        }
    }

    pub fn spec(&self) -> ModelSpec {
        self.core.read().unwrap().spec.clone()
    }

    pub fn meta(&self) -> ModelMeta {
        self.core.read().unwrap().meta.clone()
    }

    pub fn policy(&self) -> Policy {
        self.core.read().unwrap().policy.clone()
    }

    pub fn latency_table(&self) -> LatencyTable {
        self.core.read().unwrap().table.clone()
    }

    /// Number of storage-pool members serving this engine.
    pub fn devices(&self) -> usize {
        self.core.read().unwrap().pool.len()
    }

    /// Hot-stripe replication factor of the storage pool (1 = none).
    pub fn replication(&self) -> usize {
        self.core.read().unwrap().pool.stripe().replication()
    }

    /// Liveness + fault-counter snapshot of the storage pool: dead
    /// members and cumulative retries / failovers / hedges / hedge wins.
    /// `/healthz` reports "degraded" from this when a member is dead but
    /// replication keeps the pool serving.
    pub fn pool_health(&self) -> PoolHealthSnapshot {
        self.core.read().unwrap().pool.health().snapshot()
    }

    /// Wrap pool member `m` in a [`FaultInjector`] and return its
    /// control handle — the programmatic fault seam (the env-driven one
    /// is `NC_FAULT_*` at build time). Only the inline submit path sees
    /// the wrapper: async I/O workers clone member handles at build, so
    /// combine with `async_io(false)` (simulated pools are always
    /// inline). Panics if `m` is out of range.
    pub fn inject_faults(&self, m: usize, cfg: FaultConfig) -> FaultHandle {
        let mut core = self.core.write().unwrap();
        let mut handle = None;
        core.pool.wrap_members(|i, inner| {
            if i != m {
                return inner;
            }
            let fi = FaultInjector::new(inner, cfg.clone());
            handle = Some(fi.handle());
            Arc::new(fi)
        });
        handle.expect("pool member index out of range")
    }

    /// On-flash storage dtype of the weight image.
    pub fn dtype(&self) -> DType {
        self.core.read().unwrap().store.dtype()
    }

    /// Whether the asynchronous I/O pipeline is enabled.
    pub fn async_io(&self) -> bool {
        self.core.read().unwrap().async_io
    }

    /// Whether next-layer prefetch is enabled.
    pub fn prefetch(&self) -> bool {
        self.core.read().unwrap().prefetch
    }

    /// Executor kernel worker-thread count.
    pub fn exec_threads(&self) -> usize {
        self.core.read().unwrap().exec_threads
    }

    /// Configured bound on in-flight whole-layer prefetches.
    pub fn io_queue_depth(&self) -> usize {
        self.core.read().unwrap().io_queue_depth
    }

    /// Snapshot of accumulated per-stage metrics, including the pool's
    /// fault-tolerance counters (`io.retries`, `io.failovers`,
    /// `io.hedges`, `io.hedge_wins`) and `pool.dead` (dead-member count)
    /// as byte-keyed gauges — `/metrics` exposes them with no extra
    /// plumbing.
    pub fn metrics(&self) -> Metrics {
        let core = self.core.read().unwrap();
        let mut m = core.metrics.lock().unwrap().clone();
        let h = core.pool.health().snapshot();
        m.add_bytes("io.retries", h.retries);
        m.add_bytes("io.failovers", h.failovers);
        m.add_bytes("io.hedges", h.hedges);
        m.add_bytes("io.hedge_wins", h.hedge_wins);
        m.add_bytes("pool.dead", h.dead_members.len() as u64);
        if let Some(c) = &core.chunk_cache {
            m.add_bytes("cache.budget_bytes", c.budget_bytes());
            m.add_bytes("cache.resident_bytes", c.resident_bytes());
            m.add_bytes("cache.admissions", c.admissions());
            m.add_bytes("cache.evictions", c.evictions());
            m.add_bytes("cache.drift_ppm", (c.drift() * 1e6) as u64);
        }
        m
    }

    /// Pre-compile all artifacts (avoids first-request compile stalls).
    pub fn warmup(&self) -> Result<usize> {
        let core = self.core.read().unwrap();
        core.runtime.warmup(&core.model)
    }

    /// Decode one token on several sessions **cooperatively**: selection
    /// runs per stream, the per-group flash plans are fused so chunks
    /// demanded by more than one stream are read once
    /// ([`crate::plan::IoPlanner::fuse_into`]), and streams whose compute
    /// sets coincide share one gathered weight tile through the
    /// multi-stream kernels. Outputs and selected-chunk sets are
    /// **bit-identical** to solo [`Session::decode_step`] calls on the
    /// same sessions — batching is a pure throughput change.
    ///
    /// Members must be distinct sessions of this engine, each with a
    /// non-empty KV cache; the batch is validated before any member
    /// mutates, so an invalid member fails the call with every session
    /// unchanged. After validation the batch is **transactional**:
    /// every member's KV caches are marked before the pipeline runs,
    /// and an error mid-batch (e.g. a device failure mid-layer) rolls
    /// every member back before returning — a failed batch never leaves
    /// a session partially advanced, so callers may safely retry
    /// members solo (the scheduler does exactly that to isolate the
    /// failing stream). At most
    /// [`MAX_DECODE_BATCH`](crate::coordinator::MAX_DECODE_BATCH)
    /// members per call.
    pub fn decode_batch(&self, reqs: &[DecodeRequest]) -> Result<Vec<(Vec<f32>, StageStats)>> {
        let mut outs = vec![Vec::new(); reqs.len()];
        let mut stats = vec![StageStats::default(); reqs.len()];
        self.decode_batch_into(reqs, &mut outs, &mut stats)?;
        Ok(outs.into_iter().zip(stats).collect())
    }

    /// Allocation-free [`Engine::decode_batch`]: outputs and stats land
    /// in caller-owned slices (cleared + refilled, capacity reused).
    /// After one warm-up batch of a given size, further batches perform
    /// no heap allocations.
    pub fn decode_batch_into(
        &self,
        reqs: &[DecodeRequest],
        outs: &mut [Vec<f32>],
        stats: &mut [StageStats],
    ) -> Result<()> {
        for (i, r) in reqs.iter().enumerate() {
            anyhow::ensure!(
                Arc::ptr_eq(&self.core, &r.session.core),
                "batch member {i}: session belongs to a different engine"
            );
        }
        let core = self.core.read().unwrap();
        crate::coordinator::pipeline::batch::decode_batch(&core, reqs, outs, stats)
    }

    /// Run dense calibration passes, build hot–cold permutations per
    /// scored matrix, bake them into the flash layout, and invalidate all
    /// session state. Call before serving (offline step in the paper).
    pub fn calibrate_and_reorder(&self, frames: &[Vec<f32>]) -> Result<()> {
        self.core.write().unwrap().calibrate_and_reorder(frames)
    }

    /// Install a hot-neuron cache built from calibration frequencies.
    pub fn set_neuron_cache(&self, cache: HotNeuronCache) {
        self.core.write().unwrap().neuron_cache = Some(cache);
    }

    /// Shared hot-chunk RAM cache budget in MiB (0 = disabled).
    pub fn cache_mb(&self) -> usize {
        let core = self.core.read().unwrap();
        core.chunk_cache
            .as_ref()
            .map_or(0, |c| (c.budget_bytes() >> 20) as usize)
    }

    /// One maintenance pass over the shared chunk cache: decays the live
    /// selection-frequency counters, re-picks each shard's resident set
    /// under its byte share, materializes admissions from the weight
    /// store (off the decode hot path, under the core *read* lock so
    /// serving keeps running), and returns the traffic-weighted drift
    /// score of live frequency vs the calibrated baseline. If a
    /// [`EngineBuilder::drift_threshold`] is armed and drift reaches it,
    /// the engine re-reorders online from live traffic (write lock,
    /// epoch bump — sessions reset exactly as after
    /// [`Engine::calibrate_and_reorder`]). No-op returning 0.0 without a
    /// cache.
    pub fn maintain_cache(&self) -> Result<f64> {
        let (drift, threshold) = {
            let core = self.core.read().unwrap();
            let Some(cache) = &core.chunk_cache else {
                return Ok(0.0);
            };
            // Memoize decoded logical matrices across the pass: admission
            // fetches cluster on few (layer, member) pairs per pass.
            let mut mats: HashMap<MatrixId, Vec<f32>> = HashMap::new();
            let dtype = core.store.dtype();
            let drift = cache.maintain(|layer, group, member_i, chunk, dst| {
                let kind = MatrixKind::SCORED[group];
                let member = group_members(kind)[member_i];
                let id = MatrixId::new(layer, member);
                let cols = core.spec.shape_of(member).cols;
                let enc = dtype.encoded_row_bytes(cols);
                let w = mats
                    .entry(id)
                    .or_insert_with(|| core.store.logical_matrix(id));
                let perm = core.store.permutation(id);
                // Encode rows exactly as `build_image` does so cached
                // entries stay byte-identical to flash-served rows.
                for i in 0..chunk.len {
                    let p = chunk.start + i;
                    let l = perm.map_or(p, |pm| pm.old_of(p));
                    encode_row(
                        dtype,
                        &w[l * cols..(l + 1) * cols],
                        &mut dst[i * enc..(i + 1) * enc],
                    );
                }
            });
            (drift, core.drift_threshold)
        };
        if let Some(t) = threshold {
            if drift >= t {
                self.core.write().unwrap().rereorder_from_live()?;
            }
        }
        Ok(drift)
    }

    /// Cheap periodic hook for scheduler workers: counts calls and runs
    /// one [`Engine::maintain_cache`] pass every 32nd call. No-op (one
    /// relaxed atomic read) when the cache is disabled.
    pub fn cache_tick(&self) {
        {
            let core = self.core.read().unwrap();
            if core.chunk_cache.is_none() {
                return;
            }
            if core.cache_ticks.fetch_add(1, Ordering::Relaxed) % 32 != 31 {
                return;
            }
        }
        let _ = self.maintain_cache();
    }
}

/// Everything a session owns and mutates per call: serving state plus the
/// scratch arena all hot-path buffers come from. The pipeline drivers
/// (solo and batch) work directly on this pair.
pub(crate) struct SessionInner {
    pub(crate) state: SessionState,
    pub(crate) scratch: ScratchArena,
    /// In-progress chunked prefill, if any ([`Session::prefill_begin`]).
    /// A `Some` found by any *other* call means the driver abandoned the
    /// pass mid-way; the session state is half-appended and is reset
    /// before that call proceeds.
    pub(crate) pass: Option<PrefillPass>,
}

/// One serving stream: owns its KV caches, prefetch state, and scratch
/// arena; shares the engine core. `Send + Sync`: concurrent calls on the
/// same session serialize on its internal lock, calls on different
/// sessions run in parallel (and the batch driver locks several sessions
/// in address order to decode them as one fused batch).
pub struct Session {
    pub(crate) core: Arc<RwLock<EngineCore>>,
    pub(crate) inner: Mutex<SessionInner>,
}

impl Session {
    /// Append one frame of token embeddings (`[T, d]` row-major); returns
    /// the output hidden states and stage stats.
    pub fn append_frame(&self, frame: &[f32]) -> Result<(Vec<f32>, StageStats)> {
        let mut out = Vec::new();
        let stats = self.append_frame_into(frame, &mut out)?;
        Ok((out, stats))
    }

    /// Allocation-free [`Session::append_frame`]: the output hidden states
    /// are written into `out` (cleared + refilled, capacity reused).
    pub fn append_frame_into(&self, frame: &[f32], out: &mut Vec<f32>) -> Result<StageStats> {
        let core = self.core.read().unwrap();
        let mut inner = self.inner.lock().unwrap();
        let t = core.meta.t;
        anyhow::ensure!(
            frame.len() == t * core.meta.d,
            "frame must be [T={}, d={}]",
            t,
            core.meta.d
        );
        let inner = &mut *inner;
        if inner.pass.take().is_some() {
            // An abandoned chunked prefill left half-appended KV caches;
            // start this call from a clean slate.
            inner.state.reset(core.epoch);
        }
        core.forward(&mut inner.state, &mut inner.scratch, frame, t, out)
    }

    /// Decode one token (`[1, d]` embedding).
    pub fn decode_step(&self, token: &[f32]) -> Result<(Vec<f32>, StageStats)> {
        let mut out = Vec::new();
        let stats = self.decode_step_into(token, &mut out)?;
        Ok((out, stats))
    }

    /// Allocation-free [`Session::decode_step`]: the next hidden state is
    /// written into `out` (cleared + refilled, capacity reused). After one
    /// warm-up call, further calls perform no heap allocations.
    pub fn decode_step_into(&self, token: &[f32], out: &mut Vec<f32>) -> Result<StageStats> {
        let core = self.core.read().unwrap();
        let mut inner = self.inner.lock().unwrap();
        anyhow::ensure!(token.len() == core.meta.d, "token must be [d]");
        let inner = &mut *inner;
        if inner.pass.take().is_some() {
            // An abandoned chunked prefill left half-appended KV caches;
            // the reset below surfaces as the empty-KV error.
            inner.state.reset(core.epoch);
        }
        if inner.state.epoch == core.epoch {
            anyhow::ensure!(
                !inner.state.kvs.iter().all(|kv| kv.is_empty()),
                "decode requires a non-empty KV cache (append a frame first)"
            );
        } else {
            // The engine was re-calibrated since this session last ran;
            // its KV state is about to be discarded.
            anyhow::bail!("decode requires a non-empty KV cache (append a frame first)");
        }
        core.forward(&mut inner.state, &mut inner.scratch, token, 1, out)
    }

    /// Begin a chunked prefill of one frame (`[T, d]` row-major): the
    /// resumable form of [`Session::append_frame`]. No layer runs yet;
    /// drive the pass with [`Session::prefill_step`] and collect the
    /// output with [`Session::prefill_finish`]. Between calls every
    /// engine lock is released, so the caller can serve other sessions
    /// mid-pass. The chunked pass is bit-identical to a monolithic
    /// append; callers must not interleave other calls on *this* session
    /// until the pass finishes (doing so resets the session).
    pub fn prefill_begin(&self, frame: &[f32]) -> Result<()> {
        let core = self.core.read().unwrap();
        let mut inner = self.inner.lock().unwrap();
        let t = core.meta.t;
        anyhow::ensure!(
            frame.len() == t * core.meta.d,
            "frame must be [T={}, d={}]",
            t,
            core.meta.d
        );
        let inner = &mut *inner;
        if inner.pass.take().is_some() {
            inner.state.reset(core.epoch);
        }
        inner.pass = Some(core.prefill_begin(&mut inner.state, &mut inner.scratch, frame, t));
        Ok(())
    }

    /// Run up to `max_layers` more layers of the active chunked prefill.
    /// Returns `true` while layers remain. Errors (including an engine
    /// re-calibration mid-pass) abort the pass and reset the session.
    pub fn prefill_step(&self, max_layers: usize) -> Result<bool> {
        let core = self.core.read().unwrap();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let Some(pass) = inner.pass.as_mut() else {
            anyhow::bail!("no chunked prefill in progress (call prefill_begin first)");
        };
        match core.prefill_step(&mut inner.state, &mut inner.scratch, pass, max_layers) {
            Ok(more) => Ok(more),
            Err(e) => {
                inner.pass = None;
                inner.state.reset(core.epoch);
                Err(e)
            }
        }
    }

    /// Finish a completed chunked prefill: fold metrics and write the
    /// output hidden states into `out`. Errors if layers remain.
    pub fn prefill_finish(&self, out: &mut Vec<f32>) -> Result<StageStats> {
        let core = self.core.read().unwrap();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let Some(pass) = inner.pass.take() else {
            anyhow::bail!("no chunked prefill in progress (call prefill_begin first)");
        };
        if pass.pass.epoch != core.epoch || !pass.done() {
            let done = pass.layers_done();
            inner.state.reset(core.epoch);
            anyhow::bail!("chunked prefill finished early ({done} layers done); session reset");
        }
        Ok(core.prefill_finish(&mut inner.state, &mut inner.scratch, pass, out))
    }

    /// Abort an in-progress chunked prefill (if any), resetting the
    /// session: half-appended KV caches are unusable.
    pub fn prefill_abort(&self) {
        let core = self.core.read().unwrap();
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        if inner.pass.take().is_some() {
            inner.state.reset(core.epoch);
        }
    }

    /// True while a chunked prefill pass is active.
    pub fn prefill_active(&self) -> bool {
        self.inner.lock().unwrap().pass.is_some()
    }

    /// Clear KV caches and prefetch state.
    pub fn reset(&self) {
        let core = self.core.read().unwrap();
        let mut inner = self.inner.lock().unwrap();
        inner.pass = None;
        inner.state.reset(core.epoch);
    }

    /// Total KV tokens currently cached across layers.
    pub fn kv_tokens(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .state
            .kvs
            .iter()
            .map(|kv| kv.len())
            .sum()
    }
}

/// The shared, read-mostly engine state every session and both pipeline
/// drivers work against. `pub(crate)` fields: the staged pipeline
/// (`coordinator::pipeline`) is the other half of this type's
/// implementation — its stage helpers and drivers live there as inherent
/// impls.
pub(crate) struct EngineCore {
    pub(crate) model: String,
    pub(crate) policy: Policy,
    pub(crate) sparsity: f64,
    pub(crate) seed: u64,
    pub(crate) prefetch: bool,
    /// Async I/O pipeline enabled (submit-ahead prefetch + completion
    /// tickets). Pure timing change; outputs are invariant.
    pub(crate) async_io: bool,
    /// Bound on in-flight whole-layer prefetches / worker queue slots.
    pub(crate) io_queue_depth: usize,
    /// Per-member I/O workers (wall-clock pools with async I/O only).
    pub(crate) async_pipe: Option<AsyncIoQueue>,
    /// Real-storage backing directory (file-backed pools), if any.
    pub(crate) backing_dir: Option<PathBuf>,
    /// Executor kernel worker count (outputs are thread-count invariant).
    pub(crate) exec_threads: usize,
    pub(crate) runtime: XlaRuntime,
    pub(crate) meta: ModelMeta,
    pub(crate) spec: ModelSpec,
    pub(crate) store: WeightStore,
    /// Sharded storage pool (single-member pools reproduce the legacy
    /// one-device behaviour bit for bit).
    pub(crate) pool: DevicePool,
    /// One profile per pool member (homogeneous = N copies).
    pub(crate) member_profiles: Vec<DeviceProfile>,
    /// Per-member profiled `T[s]` tables.
    pub(crate) member_tables: Vec<LatencyTable>,
    pub(crate) stripe_policy: StripePolicy,
    pub(crate) stripe_bytes: Option<usize>,
    /// Hot-stripe replication factor the pool was built with.
    pub(crate) replication: usize,
    /// Pre-rendered per-member metrics keys ("io.dev0", …).
    pub(crate) dev_io_names: Vec<String>,
    /// Pre-rendered per-dtype bytes-loaded counter ("io.bytes_int8", …).
    pub(crate) io_dtype_bytes: &'static str,
    /// Byte-keyed pool-effective latency table (selection utility).
    pub(crate) table: LatencyTable,
    /// The table pre-keyed per scored row size (hot path must not clone).
    pub(crate) keyed_tables: HashMap<usize, LatencyTable>,
    /// Pre-rendered artifact names: (stage base, is_decode, bucket).
    pub(crate) artifact_names: HashMap<(&'static str, bool, usize), String>,
    pub(crate) planner: IoPlanner,
    pub(crate) selector: Option<Box<dyn Selector>>,
    /// Optional hot-neuron cache (§5 memory-budget extension).
    pub(crate) neuron_cache: Option<HotNeuronCache>,
    /// Shared cross-session hot-chunk RAM cache (None = disabled). Arc so
    /// maintenance can run against it while `self.store` is mutated.
    pub(crate) chunk_cache: Option<Arc<ChunkCache>>,
    /// Drift score past which a maintenance pass triggers online
    /// re-reordering from live traffic (None = never).
    pub(crate) drift_threshold: Option<f64>,
    /// Scheduler-driven maintenance pacing counter ([`Engine::cache_tick`]).
    pub(crate) cache_ticks: AtomicU64,
    pub(crate) metrics: Mutex<Metrics>,
    /// Pooled batch-driver working memory (fusion scratch, fused
    /// plan/receipt, cohort kernel buffers), recycled so steady-state
    /// batched decoding allocates nothing.
    pub(crate) batch_arenas: Mutex<Vec<Box<BatchArena>>>,
    /// Bumped whenever the flash image is rebuilt (re-calibration);
    /// sessions compare and self-reset.
    pub(crate) epoch: u64,
}

impl EngineCore {
    fn calibrate_and_reorder(&mut self, frames: &[Vec<f32>]) -> Result<()> {
        // Collect importance samples with a dense temporary pass.
        let mut samples: HashMap<(usize, MatrixKind), Vec<Vec<f32>>> = HashMap::new();
        for f in frames {
            let collected = self.forward_collect(f)?;
            for (key, imp) in collected {
                samples.entry(key).or_default().push(imp);
            }
        }
        // Build + install permutations, then rebuild the flash image.
        for layer in 0..self.spec.layers {
            for kind in MatrixKind::SCORED {
                let rows = self.spec.shape_of(kind).rows;
                if let Some(s) = samples.get(&(layer, kind)) {
                    let perm = HotColdReorder.build(s, rows);
                    for member in MatrixKind::ALL {
                        if member.mask_source() == kind {
                            self.store
                                .set_permutation(MatrixId::new(layer, member), perm.clone());
                        }
                    }
                }
            }
        }
        // Seed the shared chunk cache from the calibrated activation
        // profile, mapped into the new physical row order: per-shard
        // baselines for drift detection plus virtual observations so the
        // first maintenance pass admits calibration-hot rows before any
        // live traffic accumulates.
        if let Some(cache) = self.chunk_cache.clone() {
            cache.clear_all();
            let mut phys = Vec::new();
            for layer in 0..self.spec.layers {
                for (gi, kind) in MatrixKind::SCORED.into_iter().enumerate() {
                    let rows = self.spec.shape_of(kind).rows;
                    let Some(s) = samples.get(&(layer, kind)) else {
                        continue;
                    };
                    let logical = activation_frequency(s, rows);
                    phys.clear();
                    phys.resize(rows, 0.0);
                    match self.store.permutation(MatrixId::new(layer, kind)) {
                        Some(p) => {
                            for (r, v) in phys.iter_mut().enumerate() {
                                *v = logical[p.old_of(r)];
                            }
                        }
                        None => phys.copy_from_slice(&logical),
                    }
                    cache.seed_prior(layer, gi, &phys);
                }
            }
        }
        self.rebuild_pool_and_bump_epoch()
    }

    /// Shared tail of offline re-calibration and online re-reordering:
    /// re-bake the flash image into a fresh striped pool, restart async
    /// I/O workers against it, and bump the epoch so sessions self-reset.
    fn rebuild_pool_and_bump_epoch(&mut self) -> Result<()> {
        let stripe = StripeLayout::build_replicated(
            &self.store.layout,
            self.member_profiles.len(),
            self.stripe_policy,
            self.stripe_bytes,
            self.replication,
        );
        let mut pool = build_pool(
            &self.member_profiles,
            stripe,
            &self.store.build_image(),
            self.seed ^ 0xD1CE,
            self.backing_dir.as_deref(),
        )?
        .with_tables(self.member_tables.clone())
        .with_hedge(self.pool.hedge_config());
        apply_env_faults(&mut pool);
        self.pool = pool;
        // The old workers held handles to the replaced members; restart
        // them against the rebuilt pool (sharing its fresh health handle).
        self.async_pipe = (self.async_io && !self.pool.is_virtual_time()).then(|| {
            AsyncIoQueue::start_with_health(
                self.pool.member_arcs(),
                self.io_queue_depth,
                Some(self.pool.health()),
            )
        });
        self.epoch += 1;
        Ok(())
    }

    /// Online re-reordering from live traffic — the drift → re-reorder
    /// loop. Rebuilds each scored group's hot/cold permutation from the
    /// cache's live selection frequencies (mapped back to logical row
    /// space through the current permutation), re-bakes the flash image +
    /// stripe layout + pool off the serving path (callers hold the core
    /// write lock), bumps the epoch (sessions reset exactly as after
    /// offline re-calibration), and re-seeds the cache in the new
    /// physical order so residency survives the layout change as priors.
    pub(crate) fn rereorder_from_live(&mut self) -> Result<()> {
        let Some(cache) = self.chunk_cache.clone() else {
            return Ok(());
        };
        let mut live = Vec::new();
        let mut logical = Vec::new();
        let mut seeds: Vec<(usize, usize, Vec<f64>)> = Vec::new();
        for layer in 0..self.spec.layers {
            for (gi, kind) in MatrixKind::SCORED.into_iter().enumerate() {
                let rows = self.spec.shape_of(kind).rows;
                cache.frequency_snapshot(layer, gi, &mut live);
                if live.iter().sum::<f64>() <= 0.0 {
                    continue;
                }
                logical.clear();
                logical.resize(rows, 0.0);
                match self.store.permutation(MatrixId::new(layer, kind)) {
                    Some(p) => {
                        for (r, &f) in live.iter().enumerate() {
                            logical[p.old_of(r)] = f;
                        }
                    }
                    None => logical.copy_from_slice(&live),
                }
                let perm = HotColdReorder::from_frequency(&logical);
                let mut phys = vec![0.0f64; rows];
                for (r, v) in phys.iter_mut().enumerate() {
                    *v = logical[perm.old_of(r)];
                }
                for member in MatrixKind::ALL {
                    if member.mask_source() == kind {
                        self.store
                            .set_permutation(MatrixId::new(layer, member), perm.clone());
                    }
                }
                seeds.push((layer, gi, phys));
            }
        }
        self.rebuild_pool_and_bump_epoch()?;
        // Residency was keyed to the old physical order — drop it and
        // re-seed with the live profile in the new order.
        cache.clear_all();
        for (layer, gi, phys) in &seeds {
            cache.seed_prior(*layer, *gi, phys);
        }
        Ok(())
    }

    /// Dense forward that records per-(layer, scored-kind) importance —
    /// the calibration pass. Does not touch KV caches.
    fn forward_collect(&self, frame: &[f32]) -> Result<Vec<((usize, MatrixKind), Vec<f32>)>> {
        let t = self.meta.t;
        let d = self.meta.d;
        anyhow::ensure!(frame.len() == t * d, "frame must be [T, d]");
        let mut out = Vec::new();
        let mut x = frame.to_vec();
        let empty_k = KvCache::new(self.spec.cache_slots, d);
        for layer in 0..self.spec.layers {
            let hn = rmsnorm(&x, t, d);
            out.push(((layer, MatrixKind::Q), col_importance(&hn, t, d)));
            // Dense stage executions (full buckets, identity gather).
            let (attn, _k, _v) = self.exec_qkv(layer, &hn, t, &empty_k, &full_mask(d))?;
            out.push(((layer, MatrixKind::O), col_importance(&attn, t, d)));
            let x1 = self.exec_projres(layer, MatrixKind::O, &attn, t, &x, &full_mask(d))?;
            let hn2 = rmsnorm(&x1, t, d);
            out.push(((layer, MatrixKind::Gate), col_importance(&hn2, t, d)));
            let act = self.exec_gateup(layer, &hn2, t, &full_mask(d))?;
            let h = self.meta.h;
            out.push(((layer, MatrixKind::Down), col_importance(&act, t, h)));
            x = self.exec_projres(layer, MatrixKind::Down, &act, t, &x1, &full_mask(h))?;
        }
        Ok(out)
    }

    /// Dense helpers used by the calibration pass. These also flow through
    /// the planned-submit path (via [`WeightStore::read_rows`]).
    fn exec_qkv(
        &self,
        layer: usize,
        hn: &[f32],
        t: usize,
        kv: &KvCache,
        sel: &SelectionMask,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.meta.d;
        let load = |m: MatrixKind| -> Result<Vec<f32>> {
            let id = MatrixId::new(layer, m);
            let (rows, _) = self.store.read_rows(&self.pool, id, &sel.chunks)?;
            Ok(rows)
        };
        let (kc, vc, mask) = kv.tensors();
        let name = self.artifact_name("qkv", t, d)?;
        let out = self.runtime.execute(
            name,
            &[
                Tensor::new(vec![t, d], hn.to_vec()),
                Tensor::new(vec![d, d], load(MatrixKind::Q)?),
                Tensor::new(vec![d, d], load(MatrixKind::K)?),
                Tensor::new(vec![d, d], load(MatrixKind::V)?),
                kc,
                vc,
                mask,
            ],
        )?;
        Ok((out[0].data.clone(), out[1].data.clone(), out[2].data.clone()))
    }

    fn exec_gateup(&self, layer: usize, hn: &[f32], t: usize, sel: &SelectionMask) -> Result<Vec<f32>> {
        let d = self.meta.d;
        let h = self.meta.h;
        let gate = self
            .store
            .read_rows(&self.pool, MatrixId::new(layer, MatrixKind::Gate), &sel.chunks)?
            .0;
        let up = self
            .store
            .read_rows(&self.pool, MatrixId::new(layer, MatrixKind::Up), &sel.chunks)?
            .0;
        let name = self.artifact_name("gateup", t, d)?;
        let out = self.runtime.execute(
            name,
            &[
                Tensor::new(vec![t, d], hn.to_vec()),
                Tensor::new(vec![d, h], gate),
                Tensor::new(vec![d, h], up),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    fn exec_projres(
        &self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        residual: &[f32],
        sel: &SelectionMask,
    ) -> Result<Vec<f32>> {
        let d = self.meta.d;
        let rows = self.spec.shape_of(kind).rows;
        let w = self
            .store
            .read_rows(&self.pool, MatrixId::new(layer, kind), &sel.chunks)?
            .0;
        let name = self.artifact_name("projres", t, rows)?;
        let out = self.runtime.execute(
            name,
            &[
                Tensor::new(vec![t, rows], acts.to_vec()),
                Tensor::new(vec![rows, d], w),
                Tensor::new(vec![t, d], residual.to_vec()),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    /// Pre-reserve worst-case capacities for every session buffer whose
    /// length depends on selection *shape*: selections drift token to
    /// token as activations evolve, so the warm-up call alone cannot
    /// bound chunk-count-dependent vectors. Capacities are capped by the
    /// selection budget plus any hot-neuron-cache rows installed at
    /// session-open time (cached rows join the compute set on top of the
    /// budget), so this reserves the sparse working set, not the dense
    /// one. A cache installed *after* a session opens can still grow that
    /// session's gather buffers once (amortized, not steady-state). The
    /// allocation-regression test relies on this.
    fn reserve_session_buffers(&self, state: &mut SessionState, scratch: &mut ScratchArena) {
        let spec = &self.spec;
        let t_max = self.meta.t;
        let n_max = spec.d.max(spec.h);
        let max_chunks = n_max / 2 + 1;
        let keep = (1.0 - self.sparsity).clamp(0.0, 1.0);
        let kept_rows = |rows: usize| (((keep * rows as f64).round() as usize).max(1)).min(rows);
        // Worst case cached rows joining a group's compute set (any layer).
        let cached_max = |kind: MatrixKind| -> usize {
            self.neuron_cache.as_ref().map_or(0, |cache| {
                (0..spec.layers)
                    .map(|layer| cache.cached_rows(MatrixId::new(layer, kind)).len())
                    .max()
                    .unwrap_or(0)
            })
        };
        // Chunk-cache pricing mode unions resident rows into the compute
        // set the same way; the default (bit-identical) mode never grows
        // it. Bound by the shard's byte share, not current residency —
        // maintenance passes can grow residency after a session opens.
        let chunk_cached_max = |kind: MatrixKind| -> usize {
            self.chunk_cache
                .as_ref()
                .filter(|c| c.pricing())
                .map_or(0, |c| {
                    let gi = group_index(kind);
                    (0..spec.layers)
                        .map(|layer| c.max_resident_rows(layer, gi))
                        .max()
                        .unwrap_or(0)
                })
        };
        let mut group_bytes_max = 0usize;
        let mut layer_bytes = 0usize;
        let mut xs_cap = 0usize;
        let mut w_cap = 0usize;
        for kind in MatrixKind::SCORED {
            let rows = spec.shape_of(kind).rows;
            // Flash payload is budget-capped (cached rows are never
            // re-read); the gathered compute set adds cached rows.
            let kept_io = kept_rows(rows);
            let kept_compute = (kept_io + cached_max(kind) + chunk_cached_max(kind)).min(rows);
            let buckets = if kind == MatrixKind::Down {
                &self.meta.h_buckets
            } else {
                &self.meta.d_buckets
            };
            let bucket = ModelMeta::bucket_for(buckets, kept_compute);
            xs_cap = xs_cap.max(t_max * bucket);
            let mut group = 0usize;
            for member in MatrixKind::ALL {
                if member.mask_source() == kind {
                    group += kept_io * self.store.layout.row_bytes(MatrixId::new(0, member));
                    w_cap = w_cap.max(bucket * spec.shape_of(member).cols);
                }
            }
            group_bytes_max = group_bytes_max.max(group);
            layer_bytes += group;
        }
        scratch.reserve(
            n_max,
            t_max,
            max_chunks,
            xs_cap,
            w_cap,
            group_bytes_max,
            layer_bytes,
        );
        // Pool fan-out scratch: a logical command gains at most one
        // extra piece per stripe block it crosses, so per-member command
        // capacity is bounded by the plan's worst command count plus the
        // total block count; staging is bounded by a whole layer landing
        // on one member.
        let pool_cmds = 7 * max_chunks + self.pool.stripe().num_blocks() + 1;
        scratch.pool.reserve(self.pool.len(), pool_cmds, layer_bytes);
        for slot in &mut state.prefetch {
            slot.reserve(layer_bytes, 7 * max_chunks, 7 * max_chunks);
        }
        for masks in state.prev_masks.iter_mut().chain(state.next_masks.iter_mut()) {
            for group in masks.iter_mut() {
                group.reserve(max_chunks);
            }
        }
    }

    /// Pre-rendered artifact name lookup (no per-call formatting).
    pub(crate) fn artifact_name(
        &self,
        base: &'static str,
        t: usize,
        bucket: usize,
    ) -> Result<&str> {
        self.artifact_names
            .get(&(base, t == 1, bucket))
            .map(|s| s.as_str())
            .with_context(|| format!("no artifact name for {base} t={t} r={bucket}"))
    }
}

/// One [`crate::cache::ShardSpec`] per (layer, scored group), in
/// layer-major [`group_index`] order — the shard layout [`ChunkCache`]
/// expects. RAM cost per row is the *encoded* footprint of every group
/// member (quantized images stretch the budget 2–4×); the flash byte
/// credit per row is the sum of the members' on-flash row sizes (what a
/// hit saves the pool).
fn cache_shard_specs(spec: &ModelSpec, store: &WeightStore) -> Vec<crate::cache::ShardSpec> {
    let dtype = store.dtype();
    let mut specs = Vec::new();
    for layer in 0..spec.layers {
        for kind in MatrixKind::SCORED {
            let rows = spec.shape_of(kind).rows;
            let mut row_f32s = [0usize; crate::cache::MAX_MEMBERS];
            let mut row_enc_bytes = [0usize; crate::cache::MAX_MEMBERS];
            let mut flash_row_bytes_sum = 0u64;
            for (m, member) in group_members(kind).iter().enumerate() {
                let cols = spec.shape_of(*member).cols;
                row_f32s[m] = cols;
                row_enc_bytes[m] = dtype.encoded_row_bytes(cols);
                flash_row_bytes_sum += store.layout.row_bytes(MatrixId::new(layer, *member)) as u64;
            }
            specs.push(crate::cache::ShardSpec {
                rows,
                row_f32s,
                row_enc_bytes,
                flash_row_bytes_sum,
            });
        }
    }
    specs
}

/// Build the engine's storage pool: simulated members by default, or —
/// when `backing` names a directory — one wall-clock
/// [`crate::storage::RealFileDevice`] member per shard of the flash image
/// (the file-backed pool the async I/O overlap bench serves from). Files
/// are rewritten on every call, so re-calibration refreshes them too.
fn build_pool(
    profiles: &[DeviceProfile],
    stripe: StripeLayout,
    image: &[u8],
    seed: u64,
    backing: Option<&Path>,
) -> Result<DevicePool> {
    match backing {
        None => DevicePool::simulated(profiles, stripe, image, seed),
        Some(dir) => {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating backing dir {dir:?}"))?;
            let shards = stripe.shard_image(image);
            let mut paths = Vec::with_capacity(shards.len());
            for (m, data) in shards.iter().enumerate() {
                let path = dir.join(format!("member{m}.img"));
                std::fs::write(&path, data)
                    .with_context(|| format!("writing member image {path:?}"))?;
                paths.push(path);
            }
            DevicePool::from_files(&paths, stripe, 2, false)
        }
    }
}

/// Wrap every pool member in a [`FaultInjector`] when any `NC_FAULT_*`
/// knob is set (chaos CI / kill tests): members share the probabilistic
/// config but get distinct RNG seeds, and `NC_FAULT_DEAD=m` kills
/// exactly member `m` at build time. No knobs set → the pool is left
/// untouched (zero overhead on the healthy path).
fn apply_env_faults(pool: &mut DevicePool) {
    let Some(base) = FaultConfig::from_env() else {
        return;
    };
    let dead = dead_member_from_env();
    pool.wrap_members(|m, inner| {
        let cfg = FaultConfig {
            seed: base.seed ^ ((m as u64 + 1) << 32),
            dead: dead == Some(m),
            ..base.clone()
        };
        Arc::new(FaultInjector::new(inner, cfg))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;
    use crate::sparsify::ChunkSelectConfig;
    use crate::workload::FrameTrace;
    use std::time::Duration;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn build(policy: Policy, sparsity: f64) -> Engine {
        Engine::builder("tiny")
            .policy(policy)
            .sparsity(sparsity)
            .artifacts(&artifact_dir())
            .build()
            .unwrap()
    }

    fn frame(spec: &ModelSpec, idx: usize) -> Vec<f32> {
        FrameTrace::new(spec.d, spec.tokens_per_frame, 8, 7).frame(idx)
    }

    #[test]
    fn dense_engine_runs_and_is_deterministic() {
        let e1 = build(Policy::Dense, 0.0);
        let e2 = build(Policy::Dense, 0.0);
        let spec = e1.spec();
        let f = frame(&spec, 0);
        let s1 = e1.new_session();
        let s2 = e2.new_session();
        let (y1, st1) = s1.append_frame(&f).unwrap();
        let (y2, _) = s2.append_frame(&f).unwrap();
        assert_eq!(y1, y2);
        assert!(st1.io > Duration::ZERO);
        assert!(st1.compute > Duration::ZERO);
        // Dense loads every row exactly once, at the *encoded* width —
        // equal to `spec.total_bytes()` at f32, narrower when the
        // harness pins a quantized dtype via NC_DTYPE.
        let layout = crate::model::FlashLayout::build_with_dtype(&spec, false, e1.dtype());
        assert_eq!(st1.bytes_loaded, layout.total_bytes());
        assert!((st1.retained_fraction() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sparsified_output_close_to_dense() {
        let f;
        let dense_out;
        {
            let dense = build(Policy::Dense, 0.0);
            f = frame(&dense.spec(), 1);
            dense_out = dense.new_session().append_frame(&f).unwrap().0;
        }
        let sparse = build(Policy::TopK, 0.25);
        let (sparse_out, stats) = sparse.new_session().append_frame(&f).unwrap();
        assert!(stats.bytes_loaded < sparse.spec().total_bytes());
        assert!(stats.retained_fraction() < 1.0);
        assert!(stats.retained_fraction() > 0.6);
        // Output error bounded relative to signal.
        let err: f64 = dense_out
            .iter()
            .zip(&sparse_out)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let norm: f64 = dense_out.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
        assert!(err / norm < 0.5, "rel err {}", err / norm);
    }

    #[test]
    fn chunking_loads_fewer_chunks_than_topk() {
        let mk = |policy| {
            Engine::builder("tiny")
                .policy(policy)
                .sparsity(0.4)
                .seed(9)
                .artifacts(&artifact_dir())
                .build()
                .unwrap()
        };
        let topk = mk(Policy::TopK);
        let chunk = mk(Policy::Chunking {
            config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
        });
        let f = frame(&topk.spec(), 2);
        let (_, st) = topk.new_session().append_frame(&f).unwrap();
        let (_, sc) = chunk.new_session().append_frame(&f).unwrap();
        assert!(
            sc.io <= st.io,
            "chunking io {:?} should not exceed topk {:?}",
            sc.io,
            st.io
        );
    }

    #[test]
    fn decode_after_append() {
        let e = build(Policy::TopK, 0.3);
        let s = e.new_session();
        let f = frame(&e.spec(), 0);
        s.append_frame(&f).unwrap();
        let token = vec![0.1f32; e.spec().d];
        let (y, stats) = s.decode_step(&token).unwrap();
        assert_eq!(y.len(), e.spec().d);
        assert!(stats.io > Duration::ZERO);
    }

    #[test]
    fn decode_without_append_rejected() {
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        let token = vec![0.1f32; e.spec().d];
        assert!(s.decode_step(&token).is_err());
    }

    #[test]
    fn sessions_are_isolated() {
        let e = build(Policy::Dense, 0.0);
        let s0 = e.new_session();
        let s1 = e.new_session();
        let f0 = frame(&e.spec(), 0);
        let f1 = frame(&e.spec(), 5);
        // Session 1 state must not affect session 0's output.
        let y_a = s0.append_frame(&f0).unwrap().0;
        s0.reset();
        s1.append_frame(&f1).unwrap();
        let y_b = s0.append_frame(&f0).unwrap().0;
        assert_eq!(y_a, y_b);
        assert!(s1.kv_tokens() > 0);
    }

    #[test]
    fn prefetch_serves_repeat_traffic_cheaper() {
        // Dense selections are perfectly predictable, so from the second
        // call on every non-first layer is fully covered by the prefetch
        // buffer and accounted I/O cannot exceed the cold call's (the
        // prefetched whole-layer read merges into fewer, larger commands
        // and earns the compute-overlap credit on top).
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        let f = frame(&e.spec(), 3);
        let (_, cold) = s.append_frame(&f).unwrap();
        assert_eq!(cold.prefetch_hits, 0, "first call has nothing prefetched");
        let (_, warm) = s.append_frame(&f).unwrap();
        assert!(warm.prefetch_hits > 0, "repeat call should hit the buffer");
        assert!(
            warm.io <= cold.io,
            "prefetched io {:?} vs cold {:?}",
            warm.io,
            cold.io
        );
        assert!(warm.prefetched_bytes > 0);
    }

    #[test]
    fn prefetch_off_matches_outputs() {
        let on = build(Policy::TopK, 0.4);
        let off = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .prefetch(false)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        let f0 = frame(&on.spec(), 0);
        let f1 = frame(&on.spec(), 1);
        let son = on.new_session();
        let soff = off.new_session();
        // Prefetch must be a pure timing optimization: outputs identical.
        assert_eq!(
            son.append_frame(&f0).unwrap().0,
            soff.append_frame(&f0).unwrap().0
        );
        let (y_on, st_on) = son.append_frame(&f1).unwrap();
        let (y_off, st_off) = soff.append_frame(&f1).unwrap();
        assert_eq!(y_on, y_off);
        assert_eq!(st_off.prefetch_hits, 0);
        assert!(st_on.prefetch_hits > 0);
    }

    #[test]
    fn async_io_is_a_pure_timing_change() {
        let sync = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .async_io(false)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        let pipelined = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .async_io(true)
            .io_queue_depth(2)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        assert!(pipelined.async_io());
        assert_eq!(pipelined.io_queue_depth(), 2);
        let f0 = frame(&sync.spec(), 0);
        let f1 = frame(&sync.spec(), 1);
        let ss = sync.new_session();
        let sa = pipelined.new_session();
        let (y0s, st0s) = ss.append_frame(&f0).unwrap();
        let (y0a, st0a) = sa.append_frame(&f0).unwrap();
        assert_eq!(y0s, y0a, "cold outputs diverged");
        assert_eq!(st0s.bytes_loaded, st0a.bytes_loaded);
        let (y1s, _) = ss.append_frame(&f1).unwrap();
        let (y1a, st1a) = sa.append_frame(&f1).unwrap();
        assert_eq!(y1s, y1a, "warm outputs diverged");
        // The warm call has in-flight prefetches and earns overlap.
        assert!(st1a.max_inflight >= 1);
        assert!(st1a.overlapped_io > Duration::ZERO);
        let r = st1a.overlap_ratio();
        assert!((0.0..=1.0).contains(&r), "overlap ratio {r}");
        let m = pipelined.metrics();
        assert!(m.total("io.overlapped") > Duration::ZERO);
        assert!(m.bytes("io.queue_depth") >= 1);
    }

    #[test]
    fn reorder_preserves_dense_output() {
        let plain = build(Policy::Dense, 0.0);
        let reordered = build(Policy::Dense, 0.0);
        let calib: Vec<Vec<f32>> = (0..3).map(|i| frame(&plain.spec(), i)).collect();
        reordered.calibrate_and_reorder(&calib).unwrap();
        let f = frame(&plain.spec(), 6);
        let (a, _) = plain.new_session().append_frame(&f).unwrap();
        let (b, _) = reordered.new_session().append_frame(&f).unwrap();
        // Dense compute is permutation-invariant: outputs must match to
        // float tolerance (summation order changes).
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "reorder changed dense output by {max_err}");
    }

    #[test]
    fn stale_session_resets_after_recalibration() {
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        let f = frame(&e.spec(), 0);
        s.append_frame(&f).unwrap();
        assert!(s.kv_tokens() > 0);
        let calib: Vec<Vec<f32>> = (0..2).map(|i| frame(&e.spec(), i)).collect();
        e.calibrate_and_reorder(&calib).unwrap();
        // The stale session must refuse decode (its KV died with the old
        // flash image) and transparently reset on the next append.
        assert!(s.decode_step(&vec![0.1; e.spec().d]).is_err());
        s.append_frame(&f).unwrap();
        assert!(s.kv_tokens() > 0);
    }

    #[test]
    fn reorder_improves_topk_contiguity_bytes() {
        // With reordering, top-k selections form fewer/larger chunks, so
        // simulated io time should not get worse.
        let plain = build(Policy::TopK, 0.4);
        let reordered = build(Policy::TopK, 0.4);
        let calib: Vec<Vec<f32>> = (0..4).map(|i| frame(&plain.spec(), i)).collect();
        reordered.calibrate_and_reorder(&calib).unwrap();
        let f = frame(&plain.spec(), 7);
        let (_, sp) = plain.new_session().append_frame(&f).unwrap();
        let (_, sr) = reordered.new_session().append_frame(&f).unwrap();
        assert!(
            sr.io.as_secs_f64() <= sp.io.as_secs_f64() * 1.05,
            "reordered io {:?} vs plain {:?}",
            sr.io,
            sp.io
        );
    }

    #[test]
    fn engine_handles_are_cloneable_and_sync() {
        fn assert_sync_send<T: Sync + Send>() {}
        assert_sync_send::<Engine>();
        assert_sync_send::<Session>();
        let e = build(Policy::TopK, 0.3);
        let e2 = e.clone();
        let f = frame(&e.spec(), 0);
        // Sessions opened from different handles share the same core.
        let a = e.new_session().append_frame(&f).unwrap().0;
        let b = e2.new_session().append_frame(&f).unwrap().0;
        assert_eq!(a, b);
    }

    #[test]
    fn pooled_engine_bit_identical_and_reports_per_device_io() {
        let single = build(Policy::TopK, 0.4);
        let pooled = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .devices(3)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        assert_eq!(pooled.devices(), 3);
        let f = frame(&single.spec(), 2);
        let (a, sa) = single.new_session().append_frame(&f).unwrap();
        let (b, sb) = pooled.new_session().append_frame(&f).unwrap();
        // Sharding is a pure I/O-topology change: outputs and selections
        // are bit-identical to the single device.
        assert_eq!(a, b);
        assert_eq!(sa.bytes_loaded, sb.bytes_loaded);
        // Per-member accounting covers every transferred byte.
        let m = pooled.metrics();
        let dev_bytes: u64 = (0..3).map(|i| m.bytes(&format!("io.dev{i}"))).sum();
        assert_eq!(dev_bytes, sb.bytes_loaded);
        let busy = (0..3).filter(|&i| m.bytes(&format!("io.dev{i}")) > 0).count();
        assert!(busy >= 2, "striping should spread I/O over members, got {busy}");
    }

    #[test]
    fn heterogeneous_pool_serves() {
        let e = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .device_profiles(vec![DeviceProfile::nano(), DeviceProfile::agx()])
            .stripe_policy(StripePolicy::HotAware)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        assert_eq!(e.devices(), 2);
        let f = frame(&e.spec(), 1);
        let (y, st) = e.new_session().append_frame(&f).unwrap();
        assert!(y.iter().all(|v| v.is_finite()));
        assert!(st.io > Duration::ZERO);
    }

    #[test]
    fn pooled_reorder_matches_single_device() {
        let mk = |devices: usize| {
            let e = Engine::builder("tiny")
                .policy(Policy::TopK)
                .sparsity(0.4)
                .devices(devices)
                .artifacts(&artifact_dir())
                .build()
                .unwrap();
            let calib: Vec<Vec<f32>> = (0..3).map(|i| frame(&e.spec(), i)).collect();
            e.calibrate_and_reorder(&calib).unwrap();
            e.new_session().append_frame(&frame(&e.spec(), 5)).unwrap().0
        };
        assert_eq!(mk(1), mk(4));
    }

    #[test]
    fn decode_batch_matches_solo_sessions() {
        let e = build(Policy::TopK, 0.4);
        let spec = e.spec();
        // Two streams with different histories, decoded as one batch…
        let s0 = e.new_session();
        let s1 = e.new_session();
        s0.append_frame(&frame(&spec, 0)).unwrap();
        s1.append_frame(&frame(&spec, 3)).unwrap();
        // …against solo reference sessions with the same histories.
        let r0 = e.new_session();
        let r1 = e.new_session();
        r0.append_frame(&frame(&spec, 0)).unwrap();
        r1.append_frame(&frame(&spec, 3)).unwrap();
        let t0 = vec![0.05f32; spec.d];
        let t1 = vec![-0.02f32; spec.d];
        for step in 0..2 {
            let got = e
                .decode_batch(&[
                    DecodeRequest {
                        session: &s0,
                        token: &t0,
                    },
                    DecodeRequest {
                        session: &s1,
                        token: &t1,
                    },
                ])
                .unwrap();
            let (w0, st0) = r0.decode_step(&t0).unwrap();
            let (w1, st1) = r1.decode_step(&t1).unwrap();
            assert_eq!(got[0].0, w0, "stream 0 diverged at step {step}");
            assert_eq!(got[1].0, w1, "stream 1 diverged at step {step}");
            // Selected-chunk sets unchanged (observed through exact
            // bytes/importance accounting).
            assert_eq!(got[0].1.bytes_loaded, st0.bytes_loaded);
            assert_eq!(got[1].1.bytes_loaded, st1.bytes_loaded);
            assert_eq!(got[0].1.importance_kept, st0.importance_kept);
            assert_eq!(got[1].1.importance_kept, st1.importance_kept);
        }
        // Batch bookkeeping landed in the metrics: two batches of two.
        let m = e.metrics();
        assert_eq!(m.count("batch.occupancy"), 2);
        assert_eq!(m.bytes("batch.occupancy"), 4);
    }

    #[test]
    fn decode_batch_shares_overlapping_reads() {
        // Two streams fed the *same* history select the same chunks, so
        // the fused plan reads every byte once: shared bytes equal one
        // stream's worth of traffic.
        let e = build(Policy::TopK, 0.4);
        let spec = e.spec();
        let s0 = e.new_session();
        let s1 = e.new_session();
        s0.append_frame(&frame(&spec, 1)).unwrap();
        s1.append_frame(&frame(&spec, 1)).unwrap();
        let tok = vec![0.03f32; spec.d];
        let got = e
            .decode_batch(&[
                DecodeRequest {
                    session: &s0,
                    token: &tok,
                },
                DecodeRequest {
                    session: &s1,
                    token: &tok,
                },
            ])
            .unwrap();
        assert_eq!(got[0].0, got[1].0, "identical streams must stay identical");
        let m = e.metrics();
        assert!(
            m.bytes("io.shared_bytes") > 0,
            "identical selections should dedup to shared reads"
        );
    }

    #[test]
    fn decode_batch_rejects_invalid_members() {
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        s.append_frame(&frame(&e.spec(), 0)).unwrap();
        let tok = vec![0.1f32; e.spec().d];
        // Same session twice would deadlock — rejected up front.
        assert!(e
            .decode_batch(&[
                DecodeRequest {
                    session: &s,
                    token: &tok,
                },
                DecodeRequest {
                    session: &s,
                    token: &tok,
                },
            ])
            .is_err());
        // Sessions of a different engine are rejected.
        let other = build(Policy::Dense, 0.0);
        let foreign = other.new_session();
        assert!(e
            .decode_batch(&[DecodeRequest {
                session: &foreign,
                token: &tok,
            }])
            .is_err());
        // A member without KV fails the whole batch before any state
        // mutates (all-or-nothing validation).
        let empty = e.new_session();
        assert!(e
            .decode_batch(&[DecodeRequest {
                session: &empty,
                token: &tok,
            }])
            .is_err());
        // The valid session still decodes solo afterwards.
        assert!(s.decode_step(&tok).is_ok());
    }

    #[test]
    fn into_variants_match_allocating_api() {
        let e = build(Policy::TopK, 0.4);
        let f = frame(&e.spec(), 2);
        let s1 = e.new_session();
        let s2 = e.new_session();
        let (y, _) = s1.append_frame(&f).unwrap();
        let mut y2 = Vec::new();
        s2.append_frame_into(&f, &mut y2).unwrap();
        assert_eq!(y, y2);
        let token = vec![0.07f32; e.spec().d];
        let (dy, _) = s1.decode_step(&token).unwrap();
        let mut dy2 = Vec::new();
        s2.decode_step_into(&token, &mut dy2).unwrap();
        assert_eq!(dy, dy2);
    }
}
