//! The per-matrix sparsification pipeline (§3) over real XLA execution.
//!
//! For every weight matrix, per frame:
//!   score input activation → (apply offline-reorder permutation) →
//!   chunk-select under the latency model → read selected rows from flash
//!   → gather activations → zero-pad to the compiled budget bucket →
//!   execute the AOT artifact.
//!
//! A transformer block runs as four such stages (qkv+attention, o-proj,
//! gate/up, down-proj), matching the paper's "once per weight matrix,
//! ~200 times per frame" runtime structure. K/V reuse Q's mask and Up
//! reuses Gate's (they share input activations — Appendix A).

use std::collections::HashMap;
use std::path::Path;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{HotNeuronCache, KvCache, Metrics, Policy, StageTimer};
use crate::latency::{Chunk, LatencyTable};
use crate::model::{MatrixId, MatrixKind, ModelSpec, WeightStore};
use crate::reorder::HotColdReorder;
use crate::runtime::{Manifest, ModelMeta, Tensor, XlaRuntime};
use crate::sparsify::{SelectionMask, Selector};
use crate::storage::{DeviceProfile, ProfileConfig, Profiler, SimulatedSsd};

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Runnable model name ("tiny" | "small" | "base").
    pub model: String,
    /// Device profile for the simulated flash.
    pub profile: DeviceProfile,
    /// Selection policy.
    pub policy: Policy,
    /// Effective sparsity in [0, 1): fraction of rows *dropped* per matrix.
    pub sparsity: f64,
    /// Concurrent streams (each gets its own KV caches).
    pub streams: usize,
    pub seed: u64,
}

impl EngineConfig {
    pub fn new(model: &str, policy: Policy, sparsity: f64) -> Self {
        Self {
            model: model.to_string(),
            profile: DeviceProfile::nano(),
            policy,
            sparsity,
            streams: 1,
            seed: 42,
        }
    }
}

/// Per-call stage accounting (one frame append or decode step).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStats {
    /// Flash service time (virtual for simulated devices).
    pub io: Duration,
    /// XLA execution wall time.
    pub compute: Duration,
    /// Selection-algorithm wall time.
    pub select: Duration,
    /// Host gather/pad/norm wall time.
    pub host: Duration,
    pub bytes_loaded: u64,
    /// Retained / total importance this call (accuracy proxy).
    pub importance_kept: f64,
    pub importance_total: f64,
}

impl StageStats {
    pub fn end_to_end(&self) -> Duration {
        self.io + self.compute + self.select + self.host
    }

    pub fn retained_fraction(&self) -> f64 {
        if self.importance_total <= 0.0 {
            1.0
        } else {
            self.importance_kept / self.importance_total
        }
    }

    /// Merge another call's stats (used by aggregating drivers).
    pub fn absorb(&mut self, other: &StageStats) {
        self.io += other.io;
        self.compute += other.compute;
        self.select += other.select;
        self.host += other.host;
        self.bytes_loaded += other.bytes_loaded;
        self.importance_kept += other.importance_kept;
        self.importance_total += other.importance_total;
    }
}

/// The serving engine.
pub struct Engine {
    pub cfg: EngineConfig,
    runtime: XlaRuntime,
    meta: ModelMeta,
    spec: ModelSpec,
    store: WeightStore,
    device: SimulatedSsd,
    /// Byte-keyed latency table (re-keyed per matrix row size on use).
    table: LatencyTable,
    selector: Option<Box<dyn Selector>>,
    /// KV caches: [stream][layer].
    kvs: Vec<Vec<KvCache>>,
    /// Optional hot-neuron cache (§5 memory-budget extension).
    neuron_cache: Option<HotNeuronCache>,
    pub metrics: Metrics,
}

impl Engine {
    /// Build an engine, generating + "flashing" the model weights.
    pub fn new(cfg: EngineConfig, artifact_dir: &Path) -> Result<Self> {
        let runtime = XlaRuntime::open(artifact_dir)?;
        let meta = runtime
            .manifest
            .model(&cfg.model)
            .with_context(|| format!("model {} not in manifest", cfg.model))?
            .clone();
        let spec = ModelSpec::by_name(&cfg.model)
            .with_context(|| format!("unknown model {}", cfg.model))?;
        anyhow::ensure!(spec.runnable, "engine needs a runnable model");
        anyhow::ensure!(
            spec.d == meta.d && spec.h == meta.h && spec.layers == meta.layers,
            "rust spec / python manifest dimension mismatch"
        );
        let store = WeightStore::new(spec.clone(), false, cfg.seed);
        let device =
            SimulatedSsd::with_image(cfg.profile.clone(), store.build_image(), cfg.seed ^ 0xD1CE);

        // Profile T[s] against an unbounded twin of the device (the
        // analytical model is capacity-independent).
        let probe = SimulatedSsd::timing_only(cfg.profile.clone(), 1 << 40, cfg.seed ^ 0xBEEF);
        let sat = cfg.profile.saturation_bytes(0.99);
        let table = Profiler::new(&probe, ProfileConfig::coarse(sat, 1024)).build_table()?;

        let selector = cfg.policy.selector();
        let kvs = (0..cfg.streams.max(1))
            .map(|_| {
                (0..spec.layers)
                    .map(|_| KvCache::new(spec.cache_slots, spec.d))
                    .collect()
            })
            .collect();
        Ok(Self {
            cfg,
            runtime,
            meta,
            spec,
            store,
            device,
            table,
            selector,
            kvs,
            neuron_cache: None,
            metrics: Metrics::new(),
        })
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn latency_table(&self) -> &LatencyTable {
        &self.table
    }

    /// Pre-compile all artifacts (avoids first-request compile stalls).
    pub fn warmup(&self) -> Result<usize> {
        self.runtime.warmup(&self.cfg.model)
    }

    /// Run `frames` dense calibration passes, build hot–cold permutations
    /// per scored matrix, bake them into the flash layout, and clear KV
    /// state. Call before serving (offline step in the paper).
    pub fn calibrate_and_reorder(&mut self, frames: &[Vec<f32>]) -> Result<()> {
        // Collect importance samples with a dense temporary pass.
        let mut samples: HashMap<(usize, MatrixKind), Vec<Vec<f32>>> = HashMap::new();
        for f in frames {
            let collected = self.forward_collect(0, f)?;
            for (key, imp) in collected {
                samples.entry(key).or_default().push(imp);
            }
        }
        // Build + install permutations, then rebuild the flash image.
        for layer in 0..self.spec.layers {
            for kind in MatrixKind::SCORED {
                let rows = self.spec.shape_of(kind).rows;
                if let Some(s) = samples.get(&(layer, kind)) {
                    let perm = HotColdReorder.build(s, rows);
                    for member in MatrixKind::ALL {
                        if member.mask_source() == kind {
                            self.store
                                .set_permutation(MatrixId::new(layer, member), perm.clone());
                        }
                    }
                }
            }
        }
        self.device = SimulatedSsd::with_image(
            self.cfg.profile.clone(),
            self.store.build_image(),
            self.cfg.seed ^ 0xD1CE,
        );
        self.reset_streams();
        Ok(())
    }

    /// Install a hot-neuron cache built from calibration frequencies.
    pub fn set_neuron_cache(&mut self, cache: HotNeuronCache) {
        self.neuron_cache = Some(cache);
    }

    pub fn reset_streams(&mut self) {
        for stream in &mut self.kvs {
            for kv in stream {
                kv.clear();
            }
        }
    }

    /// Dense forward that records per-(layer, scored-kind) importance —
    /// the calibration pass. Does not touch KV caches.
    fn forward_collect(
        &self,
        _stream: usize,
        frame: &[f32],
    ) -> Result<Vec<((usize, MatrixKind), Vec<f32>)>> {
        let t = self.meta.t;
        let d = self.meta.d;
        anyhow::ensure!(frame.len() == t * d, "frame must be [T, d]");
        let mut out = Vec::new();
        let mut x = frame.to_vec();
        let empty_k = KvCache::new(self.spec.cache_slots, d);
        for layer in 0..self.spec.layers {
            let hn = rmsnorm(&x, t, d);
            out.push(((layer, MatrixKind::Q), col_importance(&hn, t, d)));
            // Dense stage executions (full buckets, identity gather).
            let (attn, _k, _v) = self.exec_qkv(layer, &hn, t, &empty_k, &full_mask(d))?;
            out.push(((layer, MatrixKind::O), col_importance(&attn, t, d)));
            let x1 = self.exec_projres(layer, MatrixKind::O, &attn, t, &x, &full_mask(d))?;
            let hn2 = rmsnorm(&x1, t, d);
            out.push(((layer, MatrixKind::Gate), col_importance(&hn2, t, d)));
            let act = self.exec_gateup(layer, &hn2, t, &full_mask(d))?;
            let h = self.meta.h;
            out.push(((layer, MatrixKind::Down), col_importance(&act, t, h)));
            x = self.exec_projres(layer, MatrixKind::Down, &act, t, &x1, &full_mask(h))?;
        }
        Ok(out)
    }

    /// Append one frame of token embeddings (`[T, d]` row-major) on a
    /// stream; returns the output hidden states and stage stats.
    pub fn append_frame(&mut self, stream: usize, frame: &[f32]) -> Result<(Vec<f32>, StageStats)> {
        let t = self.meta.t;
        anyhow::ensure!(
            frame.len() == t * self.meta.d,
            "frame must be [T={}, d={}]",
            t,
            self.meta.d
        );
        self.forward(stream, frame, t)
    }

    /// Decode one token (`[1, d]` embedding) on a stream.
    pub fn decode_step(&mut self, stream: usize, token: &[f32]) -> Result<(Vec<f32>, StageStats)> {
        anyhow::ensure!(token.len() == self.meta.d, "token must be [d]");
        anyhow::ensure!(
            !self.kvs[stream].iter().all(|kv| kv.is_empty()),
            "decode requires a non-empty KV cache (append a frame first)"
        );
        self.forward(stream, token, 1)
    }

    fn forward(&mut self, stream: usize, input: &[f32], t: usize) -> Result<(Vec<f32>, StageStats)> {
        anyhow::ensure!(stream < self.kvs.len(), "bad stream {stream}");
        let d = self.meta.d;
        let h = self.meta.h;
        let mut stats = StageStats::default();
        let mut x = input.to_vec();
        for layer in 0..self.spec.layers {
            // --- qkv + attention ---
            let timer = StageTimer::start();
            let hn = rmsnorm(&x, t, d);
            let imp = col_importance(&hn, t, d);
            stats.host += timer.stop(&mut self.metrics, "host");
            let sel = self.select(layer, MatrixKind::Q, &imp, &mut stats);
            let (attn, k, v) = {
                let (xs, weights, bucket, _io) =
                    self.load_group(layer, MatrixKind::Q, &hn, t, &sel, &mut stats)?;
                let timer = StageTimer::start();
                let kv = &self.kvs[stream][layer];
                let (kc, vc, mask) = kv.tensors();
                let name = self.artifact("qkv", t, bucket);
                let out = self.runtime.execute(
                    &name,
                    &[
                        Tensor::new(vec![t, bucket], xs),
                        Tensor::new(vec![bucket, d], weights[0].clone()),
                        Tensor::new(vec![bucket, d], weights[1].clone()),
                        Tensor::new(vec![bucket, d], weights[2].clone()),
                        kc,
                        vc,
                        mask,
                    ],
                )?;
                stats.compute += timer.stop(&mut self.metrics, "compute");
                (out[0].data.clone(), out[1].data.clone(), out[2].data.clone())
            };
            self.kvs[stream][layer].append(&k, &v);

            // --- o projection + residual ---
            let timer = StageTimer::start();
            let imp = col_importance(&attn, t, d);
            stats.host += timer.stop(&mut self.metrics, "host");
            let sel = self.select(layer, MatrixKind::O, &imp, &mut stats);
            let x1 = self.run_projres(layer, MatrixKind::O, &attn, t, &x, &sel, &mut stats)?;

            // --- gate/up (SwiGLU) ---
            let timer = StageTimer::start();
            let hn2 = rmsnorm(&x1, t, d);
            let imp = col_importance(&hn2, t, d);
            stats.host += timer.stop(&mut self.metrics, "host");
            let sel = self.select(layer, MatrixKind::Gate, &imp, &mut stats);
            let act = {
                let (xs, weights, bucket, _io) =
                    self.load_group(layer, MatrixKind::Gate, &hn2, t, &sel, &mut stats)?;
                let timer = StageTimer::start();
                let name = self.artifact("gateup", t, bucket);
                let out = self.runtime.execute(
                    &name,
                    &[
                        Tensor::new(vec![t, bucket], xs),
                        Tensor::new(vec![bucket, h], weights[0].clone()),
                        Tensor::new(vec![bucket, h], weights[1].clone()),
                    ],
                )?;
                stats.compute += timer.stop(&mut self.metrics, "compute");
                out[0].data.clone()
            };

            // --- down projection + residual ---
            let timer = StageTimer::start();
            let imp = col_importance(&act, t, h);
            stats.host += timer.stop(&mut self.metrics, "host");
            let sel = self.select(layer, MatrixKind::Down, &imp, &mut stats);
            x = self.run_projres(layer, MatrixKind::Down, &act, t, &x1, &sel, &mut stats)?;
        }
        self.metrics.add_bytes("io", stats.bytes_loaded);
        Ok((x, stats))
    }

    /// Run the selection policy for one scored matrix.
    fn select(
        &mut self,
        layer: usize,
        kind: MatrixKind,
        importance_logical: &[f32],
        stats: &mut StageStats,
    ) -> SelectionMask {
        let rows = importance_logical.len();
        let timer = StageTimer::start();
        // Move importance into physical (reordered) row space.
        let id = MatrixId::new(layer, kind);
        let mut imp: Vec<f32> = match self.store.permutation(id) {
            Some(p) => p.apply(importance_logical),
            None => importance_logical.to_vec(),
        };
        let total: f64 = imp.iter().map(|&v| v as f64).sum();
        // Cached rows are free: zero their importance pre-selection (§5).
        if let Some(cache) = &self.neuron_cache {
            cache.zero_cached(id, &mut imp);
        }
        let budget = ((1.0 - self.cfg.sparsity) * rows as f64).round() as usize;
        let sel = match &self.selector {
            None => SelectionMask::full(rows),
            Some(s) => {
                let row_bytes = self.spec.row_bytes(kind);
                let table = self.table.with_row_bytes(row_bytes);
                s.select(&imp, budget, &table)
            }
        };
        stats.select += timer.stop(&mut self.metrics, "select");
        stats.importance_total += total;
        stats.importance_kept += sel.captured_importance(&imp);
        if let Some(cache) = &self.neuron_cache {
            stats.importance_kept += cache.cached_importance(id, importance_logical, self.store.permutation(id));
        }
        sel
    }

    /// Load all matrices of the selection group led by `kind`, gather the
    /// activations, pad to the compiled bucket. Returns (xs, per-member
    /// weights, bucket, io-time).
    fn load_group(
        &mut self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        sel: &SelectionMask,
        stats: &mut StageStats,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>, usize, Duration)> {
        let members: Vec<MatrixKind> = MatrixKind::ALL
            .into_iter()
            .filter(|m| m.mask_source() == kind)
            .collect();
        let in_rows = self.spec.shape_of(kind).rows;

        // Union of selected + cached rows (sorted, physical space).
        let id0 = MatrixId::new(layer, kind);
        let mut phys_rows: Vec<usize> = sel.indices();
        let mut flash_chunks: Vec<Chunk> = sel.chunks.clone();
        if let Some(cache) = &self.neuron_cache {
            let cached = cache.cached_rows(id0);
            if !cached.is_empty() {
                let selset: Vec<bool> = {
                    let mut v = vec![false; in_rows];
                    for &r in &phys_rows {
                        v[r] = true;
                    }
                    v
                };
                for &r in cached {
                    if !selset[r] {
                        phys_rows.push(r);
                    }
                }
                phys_rows.sort_unstable();
                // Flash reads exclude cached rows.
                flash_chunks = sel
                    .chunks
                    .iter()
                    .flat_map(|c| cache.subtract_cached(id0, *c))
                    .collect();
            }
        }

        let buckets = if kind == MatrixKind::Down {
            &self.meta.h_buckets
        } else {
            &self.meta.d_buckets
        };
        let bucket = ModelMeta::bucket_for(buckets, phys_rows.len());

        // Gather activations: xs[:, j] = acts[:, logical(phys_rows[j])].
        let timer = StageTimer::start();
        let perm = self.store.permutation(id0);
        let mut xs = vec![0.0f32; t * bucket];
        for (j, &p) in phys_rows.iter().enumerate() {
            let logical = perm.map(|pm| pm.old_of(p)).unwrap_or(p);
            for ti in 0..t {
                xs[ti * bucket + j] = acts[ti * in_rows + logical];
            }
        }
        stats.host += timer.stop(&mut self.metrics, "host");

        // Load each member matrix: flash for selected, RAM for cached.
        let mut weights = Vec::with_capacity(members.len());
        let mut io_total = Duration::ZERO;
        for m in &members {
            let id = MatrixId::new(layer, *m);
            let cols = self.spec.shape_of(*m).cols;
            let (flash_rows, io) = self.store.read_rows(&self.device, id, &flash_chunks)?;
            io_total += io;
            let flash_bytes: u64 = flash_chunks
                .iter()
                .map(|c| (c.len * self.store.layout.row_bytes(id)) as u64)
                .sum();
            stats.bytes_loaded += flash_bytes;

            let timer = StageTimer::start();
            let mut w = vec![0.0f32; bucket * cols];
            // Merge scan: both `phys_rows` and the flash chunk rows are
            // ascending, so one forward pass pairs them without a hash
            // map (§Perf: the per-matrix HashMap was measurable on the
            // gather path).
            let mut flash_iter = flash_chunks
                .iter()
                .flat_map(|c| c.start..c.end())
                .enumerate()
                .peekable();
            for (j, &p) in phys_rows.iter().enumerate() {
                while matches!(flash_iter.peek(), Some(&(_, r)) if r < p) {
                    flash_iter.next();
                }
                if let Some(&(fpos, r)) = flash_iter.peek() {
                    if r == p {
                        w[j * cols..(j + 1) * cols]
                            .copy_from_slice(&flash_rows[fpos * cols..(fpos + 1) * cols]);
                        flash_iter.next();
                        continue;
                    }
                }
                if let Some(cache) = &self.neuron_cache {
                    if let Some(row) = cache.row_data(id, p) {
                        w[j * cols..(j + 1) * cols].copy_from_slice(row);
                    }
                }
            }
            stats.host += timer.stop(&mut self.metrics, "host");
            weights.push(w);
        }
        stats.io += io_total;
        self.metrics.add("io", io_total);
        Ok((xs, weights, bucket, io_total))
    }

    fn run_projres(
        &mut self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        residual: &[f32],
        sel: &SelectionMask,
        stats: &mut StageStats,
    ) -> Result<Vec<f32>> {
        let d = self.meta.d;
        let (xs, weights, bucket, _io) = self.load_group(layer, kind, acts, t, sel, stats)?;
        let timer = StageTimer::start();
        let name = self.artifact("projres", t, bucket);
        let out = self.runtime.execute(
            &name,
            &[
                Tensor::new(vec![t, bucket], xs),
                Tensor::new(vec![bucket, d], weights[0].clone()),
                Tensor::new(vec![t, d], residual.to_vec()),
            ],
        )?;
        stats.compute += timer.stop(&mut self.metrics, "compute");
        Ok(out[0].data.clone())
    }

    /// Dense helpers used by the calibration pass.
    fn exec_qkv(
        &self,
        layer: usize,
        hn: &[f32],
        t: usize,
        kv: &KvCache,
        sel: &SelectionMask,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.meta.d;
        let load = |m: MatrixKind| -> Result<Vec<f32>> {
            let id = MatrixId::new(layer, m);
            let (rows, _) = self.store.read_rows(&self.device, id, &sel.chunks)?;
            Ok(rows)
        };
        let (kc, vc, mask) = kv.tensors();
        let name = self.artifact("qkv", t, d);
        let out = self.runtime.execute(
            &name,
            &[
                Tensor::new(vec![t, d], hn.to_vec()),
                Tensor::new(vec![d, d], load(MatrixKind::Q)?),
                Tensor::new(vec![d, d], load(MatrixKind::K)?),
                Tensor::new(vec![d, d], load(MatrixKind::V)?),
                kc,
                vc,
                mask,
            ],
        )?;
        Ok((out[0].data.clone(), out[1].data.clone(), out[2].data.clone()))
    }

    fn exec_gateup(&self, layer: usize, hn: &[f32], t: usize, sel: &SelectionMask) -> Result<Vec<f32>> {
        let d = self.meta.d;
        let h = self.meta.h;
        let gate = self
            .store
            .read_rows(&self.device, MatrixId::new(layer, MatrixKind::Gate), &sel.chunks)?
            .0;
        let up = self
            .store
            .read_rows(&self.device, MatrixId::new(layer, MatrixKind::Up), &sel.chunks)?
            .0;
        let name = self.artifact("gateup", t, d);
        let out = self.runtime.execute(
            &name,
            &[
                Tensor::new(vec![t, d], hn.to_vec()),
                Tensor::new(vec![d, h], gate),
                Tensor::new(vec![d, h], up),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    fn exec_projres(
        &self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        residual: &[f32],
        sel: &SelectionMask,
    ) -> Result<Vec<f32>> {
        let d = self.meta.d;
        let rows = self.spec.shape_of(kind).rows;
        let w = self
            .store
            .read_rows(&self.device, MatrixId::new(layer, kind), &sel.chunks)?
            .0;
        let name = self.artifact("projres", t, rows);
        let out = self.runtime.execute(
            &name,
            &[
                Tensor::new(vec![t, rows], acts.to_vec()),
                Tensor::new(vec![rows, d], w),
                Tensor::new(vec![t, d], residual.to_vec()),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    fn artifact(&self, base: &str, t: usize, bucket: usize) -> String {
        let kind = match (base, t) {
            ("qkv", 1) => "qkv_decode".to_string(),
            ("qkv", _) => "qkv_append".to_string(),
            (b, 1) => format!("{b}_dec"),
            (b, _) => b.to_string(),
        };
        Manifest::artifact_name(&kind, &self.cfg.model, bucket)
    }
}

/// Scale-free RMSNorm over each of `t` rows of width `d` (host-side; the
/// coordinator needs the values for scoring anyway).
pub fn rmsnorm(x: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; t * d];
    for ti in 0..t {
        let row = &x[ti * d..(ti + 1) * d];
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (o, &v) in out[ti * d..(ti + 1) * d].iter_mut().zip(row) {
            *o = (v as f64 * inv) as f32;
        }
    }
    out
}

/// Mean |activation| per column over `t` tokens (§B.2's multi-token
/// importance).
pub fn col_importance(x: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut imp = vec![0.0f32; d];
    for ti in 0..t {
        for j in 0..d {
            imp[j] += x[ti * d + j].abs();
        }
    }
    let inv = 1.0 / t as f32;
    imp.iter_mut().for_each(|v| *v *= inv);
    imp
}

fn full_mask(n: usize) -> SelectionMask {
    SelectionMask::full(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;
    use crate::sparsify::ChunkSelectConfig;
    use crate::workload::FrameTrace;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn frame(spec: &ModelSpec, idx: usize) -> Vec<f32> {
        FrameTrace::new(spec.d, spec.tokens_per_frame, 8, 7).frame(idx)
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let x: Vec<f32> = (0..128).map(|i| (i as f32 - 64.0) * 0.3).collect();
        let out = rmsnorm(&x, 2, 64);
        for ti in 0..2 {
            let ms: f64 = out[ti * 64..(ti + 1) * 64]
                .iter()
                .map(|&v| (v as f64).powi(2))
                .sum::<f64>()
                / 64.0;
            assert!((ms - 1.0).abs() < 1e-3, "rms {ms}");
        }
    }

    #[test]
    fn col_importance_means_abs() {
        let x = vec![1.0f32, -2.0, 3.0, -4.0]; // t=2, d=2
        let imp = col_importance(&x, 2, 2);
        assert_eq!(imp, vec![2.0, 3.0]);
    }

    #[test]
    fn dense_engine_runs_and_is_deterministic() {
        let cfg = EngineConfig::new("tiny", Policy::Dense, 0.0);
        let mut e1 = Engine::new(cfg.clone(), &artifact_dir()).unwrap();
        let mut e2 = Engine::new(cfg, &artifact_dir()).unwrap();
        let f = frame(e1.spec(), 0);
        let (y1, s1) = e1.append_frame(0, &f).unwrap();
        let (y2, _) = e2.append_frame(0, &f).unwrap();
        assert_eq!(y1, y2);
        assert!(s1.io > Duration::ZERO);
        assert!(s1.compute > Duration::ZERO);
        assert_eq!(s1.bytes_loaded, e1.spec().total_bytes());
        assert!((s1.retained_fraction() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sparsified_output_close_to_dense() {
        let dir = artifact_dir();
        let f;
        let dense_out;
        {
            let mut dense = Engine::new(EngineConfig::new("tiny", Policy::Dense, 0.0), &dir).unwrap();
            f = frame(dense.spec(), 1);
            dense_out = dense.append_frame(0, &f).unwrap().0;
        }
        let mut sparse = Engine::new(
            EngineConfig::new("tiny", Policy::TopK, 0.25),
            &dir,
        )
        .unwrap();
        let (sparse_out, stats) = sparse.append_frame(0, &f).unwrap();
        assert!(stats.bytes_loaded < sparse.spec().total_bytes());
        assert!(stats.retained_fraction() < 1.0);
        assert!(stats.retained_fraction() > 0.6);
        // Output error bounded relative to signal.
        let err: f64 = dense_out
            .iter()
            .zip(&sparse_out)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let norm: f64 = dense_out.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
        assert!(err / norm < 0.5, "rel err {}", err / norm);
    }

    #[test]
    fn chunking_loads_fewer_chunks_than_topk() {
        let dir = artifact_dir();
        let mk = |policy| {
            let mut cfg = EngineConfig::new("tiny", policy, 0.4);
            cfg.seed = 9;
            Engine::new(cfg, &dir).unwrap()
        };
        let mut topk = mk(Policy::TopK);
        let mut chunk = mk(Policy::Chunking {
            config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
        });
        let f = frame(topk.spec(), 2);
        let (_, st) = topk.append_frame(0, &f).unwrap();
        let (_, sc) = chunk.append_frame(0, &f).unwrap();
        assert!(
            sc.io <= st.io,
            "chunking io {:?} should not exceed topk {:?}",
            sc.io,
            st.io
        );
    }

    #[test]
    fn decode_after_append() {
        let mut e = Engine::new(EngineConfig::new("tiny", Policy::TopK, 0.3), &artifact_dir()).unwrap();
        let f = frame(e.spec(), 0);
        e.append_frame(0, &f).unwrap();
        let token = vec![0.1f32; e.spec().d];
        let (y, stats) = e.decode_step(0, &token).unwrap();
        assert_eq!(y.len(), e.spec().d);
        assert!(stats.io > Duration::ZERO);
    }

    #[test]
    fn decode_without_append_rejected() {
        let mut e = Engine::new(EngineConfig::new("tiny", Policy::Dense, 0.0), &artifact_dir()).unwrap();
        let token = vec![0.1f32; e.spec().d];
        assert!(e.decode_step(0, &token).is_err());
    }

    #[test]
    fn streams_are_isolated() {
        let mut cfg = EngineConfig::new("tiny", Policy::Dense, 0.0);
        cfg.streams = 2;
        let mut e = Engine::new(cfg, &artifact_dir()).unwrap();
        let f0 = frame(e.spec(), 0);
        let f1 = frame(e.spec(), 5);
        // Stream 1 state must not affect stream 0's output.
        let y_a = e.append_frame(0, &f0).unwrap().0;
        e.reset_streams();
        e.append_frame(1, &f1).unwrap();
        let y_b = e.append_frame(0, &f0).unwrap().0;
        assert_eq!(y_a, y_b);
    }

    #[test]
    fn reorder_preserves_dense_output() {
        let dir = artifact_dir();
        let cfg = EngineConfig::new("tiny", Policy::Dense, 0.0);
        let mut plain = Engine::new(cfg.clone(), &dir).unwrap();
        let mut reordered = Engine::new(cfg, &dir).unwrap();
        let calib: Vec<Vec<f32>> = (0..3).map(|i| frame(plain.spec(), i)).collect();
        reordered.calibrate_and_reorder(&calib).unwrap();
        let f = frame(plain.spec(), 6);
        let (a, _) = plain.append_frame(0, &f).unwrap();
        let (b, _) = reordered.append_frame(0, &f).unwrap();
        // Dense compute is permutation-invariant: outputs must match to
        // float tolerance (summation order changes).
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "reorder changed dense output by {max_err}");
    }

    #[test]
    fn reorder_improves_topk_contiguity_bytes() {
        // With reordering, top-k selections form fewer/larger chunks, so
        // simulated io time should not get worse.
        let dir = artifact_dir();
        let cfg = EngineConfig::new("tiny", Policy::TopK, 0.4);
        let mut plain = Engine::new(cfg.clone(), &dir).unwrap();
        let mut reordered = Engine::new(cfg, &dir).unwrap();
        let calib: Vec<Vec<f32>> = (0..4).map(|i| frame(plain.spec(), i)).collect();
        reordered.calibrate_and_reorder(&calib).unwrap();
        let f = frame(plain.spec(), 7);
        let (_, sp) = plain.append_frame(0, &f).unwrap();
        let (_, sr) = reordered.append_frame(0, &f).unwrap();
        assert!(
            sr.io.as_secs_f64() <= sp.io.as_secs_f64() * 1.05,
            "reordered io {:?} vs plain {:?}",
            sr.io,
            sp.io
        );
    }
}
