//! The per-matrix sparsification pipeline (§3) behind a session-based
//! serving facade.
//!
//! For every weight matrix, per frame:
//!   score input activation → (apply offline-reorder permutation) →
//!   chunk-select under the latency model → **plan** the group's flash
//!   reads ([`crate::plan::IoPlanner`]) → submit one cross-matrix command
//!   batch ([`crate::storage::FlashDevice::submit`]) → gather activations
//!   → zero-pad to the compiled budget bucket → execute the stage
//!   artifact.
//!
//! A transformer block runs as four such stages (qkv+attention, o-proj,
//! gate/up, down-proj). K/V reuse Q's mask and Up reuses Gate's (they
//! share input activations — Appendix A).
//!
//! ## Sessions and prefetch
//!
//! [`Engine`] is built with [`EngineBuilder`] and serves any number of
//! independent [`Session`]s (one per stream; each owns its KV caches and
//! prefetch state). With prefetch enabled (default), the engine
//! double-buffers I/O against compute: while layer *l*'s stages execute,
//! it plans and submits layer *l+1*'s whole-layer read using the masks the
//! session selected on its *previous* call — streaming frames are
//! temporally correlated, so most of the next selection is already
//! resident when the layer is reached. Prefetched service time is charged
//! only beyond the compute it overlapped; rows the prediction missed are
//! fetched by a small residual plan.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{HotNeuronCache, KvCache, Metrics, Policy, StageTimer};
use crate::latency::{Chunk, LatencyTable};
use crate::model::{decode_f32_into, MatrixId, MatrixKind, ModelSpec, WeightStore};
use crate::plan::{CoalescePolicy, IoPlanner, PlanRequest, PlannedRead, RowCursor};
use crate::reorder::HotColdReorder;
use crate::runtime::{Manifest, ModelMeta, Tensor, XlaRuntime};
use crate::sparsify::{SelectionMask, Selector};
use crate::storage::{DeviceProfile, FlashDevice, ProfileConfig, Profiler, SimulatedSsd};

/// Per-call stage accounting (one frame append or decode step).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStats {
    /// Flash service time (virtual for simulated devices), after prefetch
    /// overlap credit.
    pub io: Duration,
    /// Stage-artifact execution wall time.
    pub compute: Duration,
    /// Selection-algorithm wall time.
    pub select: Duration,
    /// Host gather/pad/norm wall time.
    pub host: Duration,
    pub bytes_loaded: u64,
    /// Bytes loaded speculatively by the next-layer prefetcher (subset of
    /// `bytes_loaded`).
    pub prefetched_bytes: u64,
    /// Weight rows served from the prefetch buffer instead of a fresh
    /// flash read.
    pub prefetch_hits: u64,
    /// Retained / total importance this call (accuracy proxy).
    pub importance_kept: f64,
    pub importance_total: f64,
}

impl StageStats {
    pub fn end_to_end(&self) -> Duration {
        self.io + self.compute + self.select + self.host
    }

    pub fn retained_fraction(&self) -> f64 {
        if self.importance_total <= 0.0 {
            1.0
        } else {
            self.importance_kept / self.importance_total
        }
    }

    /// Merge another call's stats (used by aggregating drivers).
    pub fn absorb(&mut self, other: &StageStats) {
        self.io += other.io;
        self.compute += other.compute;
        self.select += other.select;
        self.host += other.host;
        self.bytes_loaded += other.bytes_loaded;
        self.prefetched_bytes += other.prefetched_bytes;
        self.prefetch_hits += other.prefetch_hits;
        self.importance_kept += other.importance_kept;
        self.importance_total += other.importance_total;
    }
}

/// Builder for [`Engine`] — the only way to construct one.
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    model: String,
    profile: DeviceProfile,
    policy: Policy,
    sparsity: f64,
    seed: u64,
    artifact_dir: PathBuf,
    prefetch: bool,
    coalesce: CoalescePolicy,
}

impl EngineBuilder {
    /// Start from a runnable model name ("tiny" | "small" | "base") with
    /// defaults: nano profile, dense policy, prefetch on, contiguous
    /// coalescing, artifacts in `./artifacts`.
    pub fn new(model: &str) -> Self {
        Self {
            model: model.to_string(),
            profile: DeviceProfile::nano(),
            policy: Policy::Dense,
            sparsity: 0.0,
            seed: 42,
            artifact_dir: PathBuf::from("artifacts"),
            prefetch: true,
            coalesce: CoalescePolicy::contiguous(),
        }
    }

    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Fraction of rows *dropped* per matrix, in [0, 1).
    pub fn sparsity(mut self, sparsity: f64) -> Self {
        self.sparsity = sparsity;
        self
    }

    pub fn profile(mut self, profile: DeviceProfile) -> Self {
        self.profile = profile;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn artifacts(mut self, dir: &Path) -> Self {
        self.artifact_dir = dir.to_path_buf();
        self
    }

    /// Enable/disable next-layer prefetch (default on).
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Override how plans coalesce chunk extents into device commands.
    pub fn coalesce(mut self, policy: CoalescePolicy) -> Self {
        self.coalesce = policy;
        self
    }

    /// Build the engine, generating + "flashing" the model weights.
    pub fn build(self) -> Result<Engine> {
        let runtime = XlaRuntime::open(&self.artifact_dir)?;
        let meta = runtime
            .manifest
            .model(&self.model)
            .with_context(|| format!("model {} not in manifest", self.model))?
            .clone();
        let spec = ModelSpec::by_name(&self.model)
            .with_context(|| format!("unknown model {}", self.model))?;
        anyhow::ensure!(spec.runnable, "engine needs a runnable model");
        anyhow::ensure!(
            spec.d == meta.d && spec.h == meta.h && spec.layers == meta.layers,
            "rust spec / python manifest dimension mismatch"
        );
        let store = WeightStore::new(spec.clone(), false, self.seed);
        let device = SimulatedSsd::with_image(
            self.profile.clone(),
            store.build_image(),
            self.seed ^ 0xD1CE,
        );

        // Profile T[s] against an unbounded twin of the device (the
        // analytical model is capacity-independent).
        let probe = SimulatedSsd::timing_only(self.profile.clone(), 1 << 40, self.seed ^ 0xBEEF);
        let sat = self.profile.saturation_bytes(0.99);
        let table = Profiler::new(&probe, ProfileConfig::coarse(sat, 1024)).build_table()?;

        let selector = self.policy.selector();
        let core = EngineCore {
            model: self.model,
            profile: self.profile,
            policy: self.policy,
            sparsity: self.sparsity,
            seed: self.seed,
            prefetch: self.prefetch,
            runtime,
            meta,
            spec,
            store,
            device,
            table,
            planner: IoPlanner::new(self.coalesce),
            selector,
            neuron_cache: None,
            metrics: Metrics::new(),
            epoch: 0,
        };
        Ok(Engine {
            core: Rc::new(RefCell::new(core)),
        })
    }
}

/// The serving engine facade. Cheap to clone handles out of via
/// [`Engine::new_session`]; all sessions share the flash device, weight
/// store, latency table and planner.
pub struct Engine {
    core: Rc<RefCell<EngineCore>>,
}

impl Engine {
    pub fn builder(model: &str) -> EngineBuilder {
        EngineBuilder::new(model)
    }

    /// Open an independent serving session (own KV caches, own prefetch
    /// state). Sessions must not outlive calibration epochs silently —
    /// they detect re-calibration and reset themselves.
    pub fn new_session(&self) -> Session {
        let core = self.core.borrow();
        let state = SessionState::new(&core.spec, core.epoch);
        drop(core);
        Session {
            core: self.core.clone(),
            state: RefCell::new(state),
        }
    }

    pub fn spec(&self) -> ModelSpec {
        self.core.borrow().spec.clone()
    }

    pub fn meta(&self) -> ModelMeta {
        self.core.borrow().meta.clone()
    }

    pub fn policy(&self) -> Policy {
        self.core.borrow().policy.clone()
    }

    pub fn latency_table(&self) -> LatencyTable {
        self.core.borrow().table.clone()
    }

    /// Snapshot of accumulated per-stage metrics.
    pub fn metrics(&self) -> Metrics {
        self.core.borrow().metrics.clone()
    }

    /// Pre-compile all artifacts (avoids first-request compile stalls).
    pub fn warmup(&self) -> Result<usize> {
        let core = self.core.borrow();
        core.runtime.warmup(&core.model)
    }

    /// Run dense calibration passes, build hot–cold permutations per
    /// scored matrix, bake them into the flash layout, and invalidate all
    /// session state. Call before serving (offline step in the paper).
    pub fn calibrate_and_reorder(&self, frames: &[Vec<f32>]) -> Result<()> {
        self.core.borrow_mut().calibrate_and_reorder(frames)
    }

    /// Install a hot-neuron cache built from calibration frequencies.
    pub fn set_neuron_cache(&self, cache: HotNeuronCache) {
        self.core.borrow_mut().neuron_cache = Some(cache);
    }
}

/// Group index within [`MatrixKind::SCORED`] (Q, O, Gate, Down).
fn group_index(kind: MatrixKind) -> usize {
    MatrixKind::SCORED
        .iter()
        .position(|&k| k == kind)
        .expect("scored kind")
}

/// Per-group flash-chunk demand recorded for next-call prefetch.
type GroupChunks = [Option<Vec<Chunk>>; 4];

struct SessionState {
    /// KV caches, one per layer.
    kvs: Vec<KvCache>,
    /// Flash chunks each (layer, group) demanded on the previous call —
    /// the prefetch prediction source.
    prev_masks: Vec<GroupChunks>,
    /// Prefetched whole-layer reads for the current call.
    prefetch: Vec<Option<PlannedRead>>,
    epoch: u64,
}

impl SessionState {
    fn new(spec: &ModelSpec, epoch: u64) -> Self {
        Self {
            kvs: (0..spec.layers)
                .map(|_| KvCache::new(spec.cache_slots, spec.d))
                .collect(),
            prev_masks: Vec::new(),
            prefetch: Vec::new(),
            epoch,
        }
    }

    fn reset(&mut self, epoch: u64) {
        for kv in &mut self.kvs {
            kv.clear();
        }
        self.prev_masks.clear();
        self.prefetch.clear();
        self.epoch = epoch;
    }
}

/// One serving stream: owns its KV caches and prefetch state, shares the
/// engine core.
pub struct Session {
    core: Rc<RefCell<EngineCore>>,
    state: RefCell<SessionState>,
}

impl Session {
    /// Append one frame of token embeddings (`[T, d]` row-major); returns
    /// the output hidden states and stage stats.
    pub fn append_frame(&self, frame: &[f32]) -> Result<(Vec<f32>, StageStats)> {
        let mut core = self.core.borrow_mut();
        let mut state = self.state.borrow_mut();
        let t = core.meta.t;
        anyhow::ensure!(
            frame.len() == t * core.meta.d,
            "frame must be [T={}, d={}]",
            t,
            core.meta.d
        );
        core.forward(&mut state, frame, t)
    }

    /// Decode one token (`[1, d]` embedding).
    pub fn decode_step(&self, token: &[f32]) -> Result<(Vec<f32>, StageStats)> {
        let mut core = self.core.borrow_mut();
        let mut state = self.state.borrow_mut();
        anyhow::ensure!(token.len() == core.meta.d, "token must be [d]");
        if state.epoch == core.epoch {
            anyhow::ensure!(
                !state.kvs.iter().all(|kv| kv.is_empty()),
                "decode requires a non-empty KV cache (append a frame first)"
            );
        } else {
            // The engine was re-calibrated since this session last ran;
            // its KV state is about to be discarded.
            anyhow::bail!("decode requires a non-empty KV cache (append a frame first)");
        }
        core.forward(&mut state, token, 1)
    }

    /// Clear KV caches and prefetch state.
    pub fn reset(&self) {
        let core = self.core.borrow();
        self.state.borrow_mut().reset(core.epoch);
    }

    /// Total KV tokens currently cached across layers.
    pub fn kv_tokens(&self) -> usize {
        self.state.borrow().kvs.iter().map(|kv| kv.len()).sum()
    }
}

struct EngineCore {
    model: String,
    profile: DeviceProfile,
    policy: Policy,
    sparsity: f64,
    seed: u64,
    prefetch: bool,
    runtime: XlaRuntime,
    meta: ModelMeta,
    spec: ModelSpec,
    store: WeightStore,
    device: SimulatedSsd,
    /// Byte-keyed latency table (re-keyed per matrix row size on use).
    table: LatencyTable,
    planner: IoPlanner,
    selector: Option<Box<dyn Selector>>,
    /// Optional hot-neuron cache (§5 memory-budget extension).
    neuron_cache: Option<HotNeuronCache>,
    metrics: Metrics,
    /// Bumped whenever the flash image is rebuilt (re-calibration);
    /// sessions compare and self-reset.
    epoch: u64,
}

impl EngineCore {
    fn calibrate_and_reorder(&mut self, frames: &[Vec<f32>]) -> Result<()> {
        // Collect importance samples with a dense temporary pass.
        let mut samples: HashMap<(usize, MatrixKind), Vec<Vec<f32>>> = HashMap::new();
        for f in frames {
            let collected = self.forward_collect(f)?;
            for (key, imp) in collected {
                samples.entry(key).or_default().push(imp);
            }
        }
        // Build + install permutations, then rebuild the flash image.
        for layer in 0..self.spec.layers {
            for kind in MatrixKind::SCORED {
                let rows = self.spec.shape_of(kind).rows;
                if let Some(s) = samples.get(&(layer, kind)) {
                    let perm = HotColdReorder.build(s, rows);
                    for member in MatrixKind::ALL {
                        if member.mask_source() == kind {
                            self.store
                                .set_permutation(MatrixId::new(layer, member), perm.clone());
                        }
                    }
                }
            }
        }
        self.device = SimulatedSsd::with_image(
            self.profile.clone(),
            self.store.build_image(),
            self.seed ^ 0xD1CE,
        );
        self.epoch += 1;
        Ok(())
    }

    /// Dense forward that records per-(layer, scored-kind) importance —
    /// the calibration pass. Does not touch KV caches.
    fn forward_collect(&self, frame: &[f32]) -> Result<Vec<((usize, MatrixKind), Vec<f32>)>> {
        let t = self.meta.t;
        let d = self.meta.d;
        anyhow::ensure!(frame.len() == t * d, "frame must be [T, d]");
        let mut out = Vec::new();
        let mut x = frame.to_vec();
        let empty_k = KvCache::new(self.spec.cache_slots, d);
        for layer in 0..self.spec.layers {
            let hn = rmsnorm(&x, t, d);
            out.push(((layer, MatrixKind::Q), col_importance(&hn, t, d)));
            // Dense stage executions (full buckets, identity gather).
            let (attn, _k, _v) = self.exec_qkv(layer, &hn, t, &empty_k, &full_mask(d))?;
            out.push(((layer, MatrixKind::O), col_importance(&attn, t, d)));
            let x1 = self.exec_projres(layer, MatrixKind::O, &attn, t, &x, &full_mask(d))?;
            let hn2 = rmsnorm(&x1, t, d);
            out.push(((layer, MatrixKind::Gate), col_importance(&hn2, t, d)));
            let act = self.exec_gateup(layer, &hn2, t, &full_mask(d))?;
            let h = self.meta.h;
            out.push(((layer, MatrixKind::Down), col_importance(&act, t, h)));
            x = self.exec_projres(layer, MatrixKind::Down, &act, t, &x1, &full_mask(h))?;
        }
        Ok(out)
    }

    fn forward(
        &mut self,
        state: &mut SessionState,
        input: &[f32],
        t: usize,
    ) -> Result<(Vec<f32>, StageStats)> {
        if state.epoch != self.epoch {
            state.reset(self.epoch);
        }
        let d = self.meta.d;
        let h = self.meta.h;
        let layers = self.spec.layers;
        let mut stats = StageStats::default();
        let mut next_masks: Vec<GroupChunks> =
            vec![[None, None, None, None]; layers];
        state.prefetch.resize_with(layers, || None);

        let mut x = input.to_vec();
        for layer in 0..layers {
            let layer_t0 = Instant::now();
            // Whole-layer prefetch buffer for this layer, if the previous
            // call's masks were submitted while layer-1 executed.
            let pre = state.prefetch[layer].take();

            // --- qkv + attention ---
            let timer = StageTimer::start();
            let hn = rmsnorm(&x, t, d);
            let imp = col_importance(&hn, t, d);
            stats.host += timer.stop(&mut self.metrics, "host");
            let sel = self.select(layer, MatrixKind::Q, &imp, &mut stats);
            let (attn, k, v) = {
                let (xs, weights, bucket, flash) = self.load_group(
                    layer,
                    MatrixKind::Q,
                    &hn,
                    t,
                    &sel,
                    pre.as_ref(),
                    &mut stats,
                )?;
                next_masks[layer][group_index(MatrixKind::Q)] = Some(flash);
                let timer = StageTimer::start();
                let (kc, vc, mask) = state.kvs[layer].tensors();
                let name = self.artifact("qkv", t, bucket);
                let out = self.runtime.execute(
                    &name,
                    &[
                        Tensor::new(vec![t, bucket], xs),
                        Tensor::new(vec![bucket, d], weights[0].clone()),
                        Tensor::new(vec![bucket, d], weights[1].clone()),
                        Tensor::new(vec![bucket, d], weights[2].clone()),
                        kc,
                        vc,
                        mask,
                    ],
                )?;
                stats.compute += timer.stop(&mut self.metrics, "compute");
                (out[0].data.clone(), out[1].data.clone(), out[2].data.clone())
            };
            state.kvs[layer].append(&k, &v);

            // --- o projection + residual ---
            let timer = StageTimer::start();
            let imp = col_importance(&attn, t, d);
            stats.host += timer.stop(&mut self.metrics, "host");
            let sel = self.select(layer, MatrixKind::O, &imp, &mut stats);
            let (x1, flash) =
                self.run_projres(layer, MatrixKind::O, &attn, t, &x, &sel, pre.as_ref(), &mut stats)?;
            next_masks[layer][group_index(MatrixKind::O)] = Some(flash);

            // --- gate/up (SwiGLU) ---
            let timer = StageTimer::start();
            let hn2 = rmsnorm(&x1, t, d);
            let imp = col_importance(&hn2, t, d);
            stats.host += timer.stop(&mut self.metrics, "host");
            let sel = self.select(layer, MatrixKind::Gate, &imp, &mut stats);
            let act = {
                let (xs, weights, bucket, flash) = self.load_group(
                    layer,
                    MatrixKind::Gate,
                    &hn2,
                    t,
                    &sel,
                    pre.as_ref(),
                    &mut stats,
                )?;
                next_masks[layer][group_index(MatrixKind::Gate)] = Some(flash);
                let timer = StageTimer::start();
                let name = self.artifact("gateup", t, bucket);
                let out = self.runtime.execute(
                    &name,
                    &[
                        Tensor::new(vec![t, bucket], xs),
                        Tensor::new(vec![bucket, h], weights[0].clone()),
                        Tensor::new(vec![bucket, h], weights[1].clone()),
                    ],
                )?;
                stats.compute += timer.stop(&mut self.metrics, "compute");
                out[0].data.clone()
            };

            // --- down projection + residual ---
            let timer = StageTimer::start();
            let imp = col_importance(&act, t, h);
            stats.host += timer.stop(&mut self.metrics, "host");
            let sel = self.select(layer, MatrixKind::Down, &imp, &mut stats);
            let (xn, flash) = self.run_projres(
                layer,
                MatrixKind::Down,
                &act,
                t,
                &x1,
                &sel,
                pre.as_ref(),
                &mut stats,
            )?;
            next_masks[layer][group_index(MatrixKind::Down)] = Some(flash);
            x = xn;

            // --- double-buffered prefetch of layer l+1 ---
            // Submit the next layer's predicted whole-layer read now; the
            // service time it cannot hide behind this layer's compute is
            // what the caller pays.
            if self.prefetch && layer + 1 < layers {
                self.prefetch_layer(state, layer + 1, layer_t0.elapsed(), &mut stats)?;
            }
        }
        state.prev_masks = next_masks;
        self.metrics.add_bytes("io", stats.bytes_loaded);
        Ok((x, stats))
    }

    /// Plan + submit the predicted flash demand of `layer` (all four
    /// selection groups, every member matrix — one cross-matrix command
    /// batch). `overlap` is the wall-clock compute window the prefetch
    /// hides behind.
    fn prefetch_layer(
        &mut self,
        state: &mut SessionState,
        layer: usize,
        overlap: Duration,
        stats: &mut StageStats,
    ) -> Result<()> {
        let Some(groups) = state.prev_masks.get(layer) else {
            return Ok(());
        };
        let mut requests = Vec::new();
        for (gi, scored) in MatrixKind::SCORED.into_iter().enumerate() {
            let Some(chunks) = &groups[gi] else { continue };
            if chunks.is_empty() {
                continue;
            }
            for member in MatrixKind::ALL {
                if member.mask_source() == scored {
                    requests.push(PlanRequest::new(
                        MatrixId::new(layer, member),
                        chunks.clone(),
                    ));
                }
            }
        }
        if requests.is_empty() {
            return Ok(());
        }
        let plan = self
            .planner
            .plan(&self.store.layout, &requests, Some(&self.table));
        if plan.is_empty() {
            return Ok(());
        }
        let receipt = self.device.submit(&plan)?;
        let read = PlannedRead { plan, receipt };
        let service = read.service();
        let charged = service.saturating_sub(overlap);
        stats.io += charged;
        stats.bytes_loaded += read.plan.payload_bytes();
        stats.prefetched_bytes += read.plan.payload_bytes();
        self.metrics.add("io", charged);
        self.metrics.add("prefetch", service);
        state.prefetch[layer] = Some(read);
        Ok(())
    }

    /// Run the selection policy for one scored matrix.
    fn select(
        &mut self,
        layer: usize,
        kind: MatrixKind,
        importance_logical: &[f32],
        stats: &mut StageStats,
    ) -> SelectionMask {
        let rows = importance_logical.len();
        let timer = StageTimer::start();
        // Move importance into physical (reordered) row space.
        let id = MatrixId::new(layer, kind);
        let mut imp: Vec<f32> = match self.store.permutation(id) {
            Some(p) => p.apply(importance_logical),
            None => importance_logical.to_vec(),
        };
        let total: f64 = imp.iter().map(|&v| v as f64).sum();
        // Cached rows are free: zero their importance pre-selection (§5).
        if let Some(cache) = &self.neuron_cache {
            cache.zero_cached(id, &mut imp);
        }
        let budget = ((1.0 - self.sparsity) * rows as f64).round() as usize;
        let sel = match &self.selector {
            None => SelectionMask::full(rows),
            Some(s) => {
                let row_bytes = self.spec.row_bytes(kind);
                let table = self.table.with_row_bytes(row_bytes);
                s.select(&imp, budget, &table)
            }
        };
        stats.select += timer.stop(&mut self.metrics, "select");
        stats.importance_total += total;
        stats.importance_kept += sel.captured_importance(&imp);
        if let Some(cache) = &self.neuron_cache {
            stats.importance_kept +=
                cache.cached_importance(id, importance_logical, self.store.permutation(id));
        }
        sel
    }

    /// Load all matrices of the selection group led by `kind`, gather the
    /// activations, pad to the compiled bucket. One planned, cross-matrix
    /// flash submission serves every member; rows already resident in the
    /// layer prefetch buffer or the hot-neuron cache are not re-read.
    ///
    /// Returns (xs, per-member weights, bucket, flash chunk demand).
    #[allow(clippy::too_many_arguments)]
    fn load_group(
        &mut self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        sel: &SelectionMask,
        prefetched: Option<&PlannedRead>,
        stats: &mut StageStats,
    ) -> Result<(Vec<f32>, Vec<Vec<f32>>, usize, Vec<Chunk>)> {
        let members: Vec<MatrixKind> = MatrixKind::ALL
            .into_iter()
            .filter(|m| m.mask_source() == kind)
            .collect();
        let in_rows = self.spec.shape_of(kind).rows;

        // Union of selected + cached rows (sorted, physical space).
        let id0 = MatrixId::new(layer, kind);
        let mut phys_rows: Vec<usize> = sel.indices();
        let mut flash_chunks: Vec<Chunk> = sel.chunks.clone();
        if let Some(cache) = &self.neuron_cache {
            let cached = cache.cached_rows(id0);
            if !cached.is_empty() {
                let selset: Vec<bool> = {
                    let mut v = vec![false; in_rows];
                    for &r in &phys_rows {
                        v[r] = true;
                    }
                    v
                };
                for &r in cached {
                    if !selset[r] {
                        phys_rows.push(r);
                    }
                }
                phys_rows.sort_unstable();
                // Flash reads exclude cached rows.
                flash_chunks = sel
                    .chunks
                    .iter()
                    .flat_map(|c| cache.subtract_cached(id0, *c))
                    .collect();
            }
        }

        let buckets = if kind == MatrixKind::Down {
            &self.meta.h_buckets
        } else {
            &self.meta.d_buckets
        };
        let bucket = ModelMeta::bucket_for(buckets, phys_rows.len());

        // Gather activations: xs[:, j] = acts[:, logical(phys_rows[j])].
        let timer = StageTimer::start();
        let perm = self.store.permutation(id0);
        let mut xs = vec![0.0f32; t * bucket];
        for (j, &p) in phys_rows.iter().enumerate() {
            let logical = perm.map(|pm| pm.old_of(p)).unwrap_or(p);
            for ti in 0..t {
                xs[ti * bucket + j] = acts[ti * in_rows + logical];
            }
        }
        stats.host += timer.stop(&mut self.metrics, "host");

        // Rows the prefetch buffer already holds need no fresh read; the
        // residual demand is planned as one cross-matrix batch. Coverage is
        // identical across members (the prefetcher requested the same
        // chunks for each), so the lead member's cursor decides.
        let residual: Vec<Chunk> = match prefetched {
            None => flash_chunks.clone(),
            Some(pre) => {
                let lead = MatrixId::new(layer, members[0]);
                let mut cursor = RowCursor::new(pre, lead);
                let mut out = Vec::new();
                for c in &flash_chunks {
                    let mut run: Option<usize> = None;
                    for r in c.start..c.end() {
                        if cursor.advance_to(r).is_some() {
                            if let Some(s) = run.take() {
                                out.push(Chunk::new(s, r - s));
                            }
                        } else if run.is_none() {
                            run = Some(r);
                        }
                    }
                    if let Some(s) = run {
                        out.push(Chunk::new(s, c.end() - s));
                    }
                }
                out
            }
        };

        // One planned submission for every member's residual rows.
        let requests: Vec<PlanRequest> = members
            .iter()
            .map(|m| PlanRequest::new(MatrixId::new(layer, *m), residual.clone()))
            .collect();
        let plan = self
            .planner
            .plan(&self.store.layout, &requests, Some(&self.table));
        let fresh = if plan.is_empty() {
            None
        } else {
            let receipt = self.device.submit(&plan)?;
            Some(PlannedRead { plan, receipt })
        };
        let io_total = fresh.as_ref().map(|f| f.service()).unwrap_or_default();
        if let Some(f) = &fresh {
            stats.bytes_loaded += f.plan.payload_bytes();
        }

        // Assemble per-member weight buckets: fresh read → prefetch buffer
        // → hot-neuron cache, walking phys_rows in ascending order.
        let timer = StageTimer::start();
        let mut weights = Vec::with_capacity(members.len());
        for m in &members {
            let id = MatrixId::new(layer, *m);
            let cols = self.spec.shape_of(*m).cols;
            let mut w = vec![0.0f32; bucket * cols];
            let mut fresh_cursor = fresh.as_ref().map(|f| RowCursor::new(f, id));
            let mut pre_cursor = prefetched.map(|p| RowCursor::new(p, id));
            for (j, &p) in phys_rows.iter().enumerate() {
                let dst = &mut w[j * cols..(j + 1) * cols];
                if let Some(bytes) = fresh_cursor.as_mut().and_then(|c| c.advance_to(p)) {
                    decode_f32_into(bytes, dst);
                    continue;
                }
                if let Some(bytes) = pre_cursor.as_mut().and_then(|c| c.advance_to(p)) {
                    decode_f32_into(bytes, dst);
                    stats.prefetch_hits += 1;
                    continue;
                }
                if let Some(cache) = &self.neuron_cache {
                    if let Some(row) = cache.row_data(id, p) {
                        dst.copy_from_slice(row);
                    }
                }
            }
            weights.push(w);
        }
        stats.host += timer.stop(&mut self.metrics, "host");

        stats.io += io_total;
        self.metrics.add("io", io_total);
        Ok((xs, weights, bucket, flash_chunks))
    }

    #[allow(clippy::too_many_arguments)]
    fn run_projres(
        &mut self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        residual: &[f32],
        sel: &SelectionMask,
        prefetched: Option<&PlannedRead>,
        stats: &mut StageStats,
    ) -> Result<(Vec<f32>, Vec<Chunk>)> {
        let d = self.meta.d;
        let (xs, weights, bucket, flash) =
            self.load_group(layer, kind, acts, t, sel, prefetched, stats)?;
        let timer = StageTimer::start();
        let name = self.artifact("projres", t, bucket);
        let out = self.runtime.execute(
            &name,
            &[
                Tensor::new(vec![t, bucket], xs),
                Tensor::new(vec![bucket, d], weights[0].clone()),
                Tensor::new(vec![t, d], residual.to_vec()),
            ],
        )?;
        stats.compute += timer.stop(&mut self.metrics, "compute");
        Ok((out[0].data.clone(), flash))
    }

    /// Dense helpers used by the calibration pass. These also flow through
    /// the planned-submit path (via [`WeightStore::read_rows`]).
    fn exec_qkv(
        &self,
        layer: usize,
        hn: &[f32],
        t: usize,
        kv: &KvCache,
        sel: &SelectionMask,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.meta.d;
        let load = |m: MatrixKind| -> Result<Vec<f32>> {
            let id = MatrixId::new(layer, m);
            let (rows, _) = self.store.read_rows(&self.device, id, &sel.chunks)?;
            Ok(rows)
        };
        let (kc, vc, mask) = kv.tensors();
        let name = self.artifact("qkv", t, d);
        let out = self.runtime.execute(
            &name,
            &[
                Tensor::new(vec![t, d], hn.to_vec()),
                Tensor::new(vec![d, d], load(MatrixKind::Q)?),
                Tensor::new(vec![d, d], load(MatrixKind::K)?),
                Tensor::new(vec![d, d], load(MatrixKind::V)?),
                kc,
                vc,
                mask,
            ],
        )?;
        Ok((out[0].data.clone(), out[1].data.clone(), out[2].data.clone()))
    }

    fn exec_gateup(&self, layer: usize, hn: &[f32], t: usize, sel: &SelectionMask) -> Result<Vec<f32>> {
        let d = self.meta.d;
        let h = self.meta.h;
        let gate = self
            .store
            .read_rows(&self.device, MatrixId::new(layer, MatrixKind::Gate), &sel.chunks)?
            .0;
        let up = self
            .store
            .read_rows(&self.device, MatrixId::new(layer, MatrixKind::Up), &sel.chunks)?
            .0;
        let name = self.artifact("gateup", t, d);
        let out = self.runtime.execute(
            &name,
            &[
                Tensor::new(vec![t, d], hn.to_vec()),
                Tensor::new(vec![d, h], gate),
                Tensor::new(vec![d, h], up),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    fn exec_projres(
        &self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        residual: &[f32],
        sel: &SelectionMask,
    ) -> Result<Vec<f32>> {
        let d = self.meta.d;
        let rows = self.spec.shape_of(kind).rows;
        let w = self
            .store
            .read_rows(&self.device, MatrixId::new(layer, kind), &sel.chunks)?
            .0;
        let name = self.artifact("projres", t, rows);
        let out = self.runtime.execute(
            &name,
            &[
                Tensor::new(vec![t, rows], acts.to_vec()),
                Tensor::new(vec![rows, d], w),
                Tensor::new(vec![t, d], residual.to_vec()),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    fn artifact(&self, base: &str, t: usize, bucket: usize) -> String {
        let kind = match (base, t) {
            ("qkv", 1) => "qkv_decode".to_string(),
            ("qkv", _) => "qkv_append".to_string(),
            (b, 1) => format!("{b}_dec"),
            (b, _) => b.to_string(),
        };
        Manifest::artifact_name(&kind, &self.model, bucket)
    }
}

/// Scale-free RMSNorm over each of `t` rows of width `d` (host-side; the
/// coordinator needs the values for scoring anyway).
pub fn rmsnorm(x: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; t * d];
    for ti in 0..t {
        let row = &x[ti * d..(ti + 1) * d];
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (o, &v) in out[ti * d..(ti + 1) * d].iter_mut().zip(row) {
            *o = (v as f64 * inv) as f32;
        }
    }
    out
}

/// Mean |activation| per column over `t` tokens (§B.2's multi-token
/// importance).
pub fn col_importance(x: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut imp = vec![0.0f32; d];
    for ti in 0..t {
        for j in 0..d {
            imp[j] += x[ti * d + j].abs();
        }
    }
    let inv = 1.0 / t as f32;
    imp.iter_mut().for_each(|v| *v *= inv);
    imp
}

fn full_mask(n: usize) -> SelectionMask {
    SelectionMask::full(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;
    use crate::sparsify::ChunkSelectConfig;
    use crate::workload::FrameTrace;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn build(policy: Policy, sparsity: f64) -> Engine {
        Engine::builder("tiny")
            .policy(policy)
            .sparsity(sparsity)
            .artifacts(&artifact_dir())
            .build()
            .unwrap()
    }

    fn frame(spec: &ModelSpec, idx: usize) -> Vec<f32> {
        FrameTrace::new(spec.d, spec.tokens_per_frame, 8, 7).frame(idx)
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let x: Vec<f32> = (0..128).map(|i| (i as f32 - 64.0) * 0.3).collect();
        let out = rmsnorm(&x, 2, 64);
        for ti in 0..2 {
            let ms: f64 = out[ti * 64..(ti + 1) * 64]
                .iter()
                .map(|&v| (v as f64).powi(2))
                .sum::<f64>()
                / 64.0;
            assert!((ms - 1.0).abs() < 1e-3, "rms {ms}");
        }
    }

    #[test]
    fn col_importance_means_abs() {
        let x = vec![1.0f32, -2.0, 3.0, -4.0]; // t=2, d=2
        let imp = col_importance(&x, 2, 2);
        assert_eq!(imp, vec![2.0, 3.0]);
    }

    #[test]
    fn dense_engine_runs_and_is_deterministic() {
        let e1 = build(Policy::Dense, 0.0);
        let e2 = build(Policy::Dense, 0.0);
        let spec = e1.spec();
        let f = frame(&spec, 0);
        let s1 = e1.new_session();
        let s2 = e2.new_session();
        let (y1, st1) = s1.append_frame(&f).unwrap();
        let (y2, _) = s2.append_frame(&f).unwrap();
        assert_eq!(y1, y2);
        assert!(st1.io > Duration::ZERO);
        assert!(st1.compute > Duration::ZERO);
        assert_eq!(st1.bytes_loaded, spec.total_bytes());
        assert!((st1.retained_fraction() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sparsified_output_close_to_dense() {
        let f;
        let dense_out;
        {
            let dense = build(Policy::Dense, 0.0);
            f = frame(&dense.spec(), 1);
            dense_out = dense.new_session().append_frame(&f).unwrap().0;
        }
        let sparse = build(Policy::TopK, 0.25);
        let (sparse_out, stats) = sparse.new_session().append_frame(&f).unwrap();
        assert!(stats.bytes_loaded < sparse.spec().total_bytes());
        assert!(stats.retained_fraction() < 1.0);
        assert!(stats.retained_fraction() > 0.6);
        // Output error bounded relative to signal.
        let err: f64 = dense_out
            .iter()
            .zip(&sparse_out)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let norm: f64 = dense_out.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
        assert!(err / norm < 0.5, "rel err {}", err / norm);
    }

    #[test]
    fn chunking_loads_fewer_chunks_than_topk() {
        let mk = |policy| {
            Engine::builder("tiny")
                .policy(policy)
                .sparsity(0.4)
                .seed(9)
                .artifacts(&artifact_dir())
                .build()
                .unwrap()
        };
        let topk = mk(Policy::TopK);
        let chunk = mk(Policy::Chunking {
            config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
        });
        let f = frame(&topk.spec(), 2);
        let (_, st) = topk.new_session().append_frame(&f).unwrap();
        let (_, sc) = chunk.new_session().append_frame(&f).unwrap();
        assert!(
            sc.io <= st.io,
            "chunking io {:?} should not exceed topk {:?}",
            sc.io,
            st.io
        );
    }

    #[test]
    fn decode_after_append() {
        let e = build(Policy::TopK, 0.3);
        let s = e.new_session();
        let f = frame(&e.spec(), 0);
        s.append_frame(&f).unwrap();
        let token = vec![0.1f32; e.spec().d];
        let (y, stats) = s.decode_step(&token).unwrap();
        assert_eq!(y.len(), e.spec().d);
        assert!(stats.io > Duration::ZERO);
    }

    #[test]
    fn decode_without_append_rejected() {
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        let token = vec![0.1f32; e.spec().d];
        assert!(s.decode_step(&token).is_err());
    }

    #[test]
    fn sessions_are_isolated() {
        let e = build(Policy::Dense, 0.0);
        let s0 = e.new_session();
        let s1 = e.new_session();
        let f0 = frame(&e.spec(), 0);
        let f1 = frame(&e.spec(), 5);
        // Session 1 state must not affect session 0's output.
        let y_a = s0.append_frame(&f0).unwrap().0;
        s0.reset();
        s1.append_frame(&f1).unwrap();
        let y_b = s0.append_frame(&f0).unwrap().0;
        assert_eq!(y_a, y_b);
        assert!(s1.kv_tokens() > 0);
    }

    #[test]
    fn prefetch_serves_repeat_traffic_cheaper() {
        // Dense selections are perfectly predictable, so from the second
        // call on every non-first layer is fully covered by the prefetch
        // buffer and accounted I/O cannot exceed the cold call's (the
        // prefetched whole-layer read merges into fewer, larger commands
        // and earns the compute-overlap credit on top).
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        let f = frame(&e.spec(), 3);
        let (_, cold) = s.append_frame(&f).unwrap();
        assert_eq!(cold.prefetch_hits, 0, "first call has nothing prefetched");
        let (_, warm) = s.append_frame(&f).unwrap();
        assert!(warm.prefetch_hits > 0, "repeat call should hit the buffer");
        assert!(
            warm.io <= cold.io,
            "prefetched io {:?} vs cold {:?}",
            warm.io,
            cold.io
        );
        assert!(warm.prefetched_bytes > 0);
    }

    #[test]
    fn prefetch_off_matches_outputs() {
        let on = build(Policy::TopK, 0.4);
        let off = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .prefetch(false)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        let f0 = frame(&on.spec(), 0);
        let f1 = frame(&on.spec(), 1);
        let son = on.new_session();
        let soff = off.new_session();
        // Prefetch must be a pure timing optimization: outputs identical.
        assert_eq!(
            son.append_frame(&f0).unwrap().0,
            soff.append_frame(&f0).unwrap().0
        );
        let (y_on, st_on) = son.append_frame(&f1).unwrap();
        let (y_off, st_off) = soff.append_frame(&f1).unwrap();
        assert_eq!(y_on, y_off);
        assert_eq!(st_off.prefetch_hits, 0);
        assert!(st_on.prefetch_hits > 0);
    }

    #[test]
    fn reorder_preserves_dense_output() {
        let plain = build(Policy::Dense, 0.0);
        let reordered = build(Policy::Dense, 0.0);
        let calib: Vec<Vec<f32>> = (0..3).map(|i| frame(&plain.spec(), i)).collect();
        reordered.calibrate_and_reorder(&calib).unwrap();
        let f = frame(&plain.spec(), 6);
        let (a, _) = plain.new_session().append_frame(&f).unwrap();
        let (b, _) = reordered.new_session().append_frame(&f).unwrap();
        // Dense compute is permutation-invariant: outputs must match to
        // float tolerance (summation order changes).
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "reorder changed dense output by {max_err}");
    }

    #[test]
    fn stale_session_resets_after_recalibration() {
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        let f = frame(&e.spec(), 0);
        s.append_frame(&f).unwrap();
        assert!(s.kv_tokens() > 0);
        let calib: Vec<Vec<f32>> = (0..2).map(|i| frame(&e.spec(), i)).collect();
        e.calibrate_and_reorder(&calib).unwrap();
        // The stale session must refuse decode (its KV died with the old
        // flash image) and transparently reset on the next append.
        assert!(s.decode_step(&vec![0.1; e.spec().d]).is_err());
        s.append_frame(&f).unwrap();
        assert!(s.kv_tokens() > 0);
    }

    #[test]
    fn reorder_improves_topk_contiguity_bytes() {
        // With reordering, top-k selections form fewer/larger chunks, so
        // simulated io time should not get worse.
        let plain = build(Policy::TopK, 0.4);
        let reordered = build(Policy::TopK, 0.4);
        let calib: Vec<Vec<f32>> = (0..4).map(|i| frame(&plain.spec(), i)).collect();
        reordered.calibrate_and_reorder(&calib).unwrap();
        let f = frame(&plain.spec(), 7);
        let (_, sp) = plain.new_session().append_frame(&f).unwrap();
        let (_, sr) = reordered.new_session().append_frame(&f).unwrap();
        assert!(
            sr.io.as_secs_f64() <= sp.io.as_secs_f64() * 1.05,
            "reordered io {:?} vs plain {:?}",
            sr.io,
            sp.io
        );
    }
}
