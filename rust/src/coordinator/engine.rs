//! The per-matrix sparsification pipeline (§3) behind a session-based
//! serving facade.
//!
//! For every weight matrix, per frame:
//!   score input activation → (apply offline-reorder permutation) →
//!   chunk-select under the (pool-effective) latency model → **plan**
//!   the group's flash reads ([`crate::plan::IoPlanner`]) → **shard**
//!   the plan across the storage pool's members
//!   ([`crate::plan::IoPlanner::shard_into`]) → fan one cross-matrix
//!   command batch out per member
//!   ([`crate::storage::DevicePool::submit_sharded_into`]; a
//!   single-member pool degenerates to the historical
//!   [`crate::storage::FlashDevice::submit`] path) → gather activations
//!   → zero-pad to the compiled budget bucket → execute the stage
//!   artifact. Pool service time is the max over members; per-member
//!   bytes/latency land in the metrics so utilization skew is
//!   observable.
//!
//! A transformer block runs as four such stages (qkv+attention, o-proj,
//! gate/up, down-proj). K/V reuse Q's mask and Up reuses Gate's (they
//! share input activations — Appendix A).
//!
//! ## Sessions, prefetch, and the allocation-free hot path
//!
//! [`Engine`] is built with [`EngineBuilder`] and serves any number of
//! independent [`Session`]s (one per stream; each owns its KV caches,
//! prefetch state, and a [`ScratchArena`]). The engine core is `Sync`:
//! read-mostly state lives behind an `Arc<RwLock<..>>` shared by every
//! session handle, so sessions on different threads serve concurrently
//! over one engine ([`crate::coordinator::Scheduler`] runs a worker pool
//! on exactly this property). Mutable per-stream state is owned by the
//! `Session` itself.
//!
//! The steady-state serving path performs **zero heap allocations**:
//! activations, gather staging, selection scratch, plan/receipt buffers
//! and executor temporaries all come from the session's arena, weights
//! are staged once into pooled buckets and handed to the executor as
//! borrowed [`TensorView`]s (no clones), and every `*_into` API reuses
//! capacity warmed up on the first call. An allocation-counting
//! integration test enforces this with the default single-threaded
//! kernels; `exec_threads > 1` additionally spawns scoped worker threads
//! per stage, whose transient per-thread state allocates (by design —
//! that mode trades arena purity for kernel parallelism).
//!
//! With prefetch enabled (default), the engine double-buffers I/O against
//! compute: while layer *l*'s stages execute, it plans and submits layer
//! *l+1*'s whole-layer read using the masks the session selected on its
//! *previous* call — streaming frames are temporally correlated, so most
//! of the next selection is already resident when the layer is reached.
//! Prefetched service time is charged only beyond the compute it
//! overlapped; rows the prediction missed are fetched by a small residual
//! plan.
//!
//! ## Asynchronous I/O pipeline
//!
//! With `async_io` on ([`EngineBuilder::async_io`], `NC_ASYNC_IO=1`), the
//! inline double-buffering becomes a real pipeline: up to
//! [`EngineBuilder::io_queue_depth`] whole-layer prefetches are submitted
//! *before* the kernels of the layers they overlap run, and each is
//! awaited only at the moment its layer consumes the weights. Wall-clock
//! pool members route submissions through per-member I/O worker threads
//! behind bounded queues ([`crate::storage::AsyncIoQueue`]), so flash
//! reads genuinely proceed while kernels execute; virtual-clock members
//! ([`crate::storage::SimulatedSsd`]) submit inline and credit the
//! overlap analytically — each stage pays `max(compute, io)` — keeping
//! the latency model exact and deterministic. Either way the pipeline is
//! a pure timing change: outputs and selected chunks are bit-identical
//! to the synchronous path at every queue depth and pool size, and the
//! virtual-time serving path stays allocation-free.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::arena::ScratchArena;
use crate::coordinator::{HotNeuronCache, KvCache, Metrics, Policy, StageTimer};
use crate::latency::{Chunk, LatencyTable};
use crate::model::{decode_f32_into, MatrixId, MatrixKind, ModelSpec, WeightStore};
use crate::plan::{
    CoalescePolicy, IoPlanner, PlanReceipt, PlanScratch, PlannedRead, ReadPlan, RowCursor,
};
use crate::reorder::HotColdReorder;
use crate::runtime::{Manifest, ModelMeta, Tensor, TensorView, XlaRuntime};
use crate::sparsify::{SelectScratch, SelectionMask, Selector};
use crate::storage::{
    AsyncIoQueue, DevicePool, DeviceProfile, FlashDevice, IoTicket, PoolScratch, ProfileConfig,
    Profiler, SimulatedSsd, StripeLayout, StripePolicy,
};

/// Per-call stage accounting (one frame append or decode step).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStats {
    /// Flash service time (virtual for simulated devices), after prefetch
    /// overlap credit.
    pub io: Duration,
    /// Stage-artifact execution wall time.
    pub compute: Duration,
    /// Selection-algorithm wall time.
    pub select: Duration,
    /// Host gather/pad/norm wall time.
    pub host: Duration,
    pub bytes_loaded: u64,
    /// Bytes loaded speculatively by the next-layer prefetcher (subset of
    /// `bytes_loaded`).
    pub prefetched_bytes: u64,
    /// Weight rows served from the prefetch buffer instead of a fresh
    /// flash read.
    pub prefetch_hits: u64,
    /// Flash service time hidden behind compute by the prefetch pipeline
    /// (the overlap credit already subtracted from `io`).
    pub overlapped_io: Duration,
    /// Highest number of whole-layer prefetches in flight at once (async
    /// I/O pipeline only; 0 otherwise).
    pub max_inflight: u64,
    /// Retained / total importance this call (accuracy proxy).
    pub importance_kept: f64,
    pub importance_total: f64,
}

impl StageStats {
    pub fn end_to_end(&self) -> Duration {
        self.io + self.compute + self.select + self.host
    }

    /// Fraction of total flash service time that was hidden behind
    /// compute (`overlapped / (charged + overlapped)`), in [0, 1].
    pub fn overlap_ratio(&self) -> f64 {
        let total = self.io + self.overlapped_io;
        if total.is_zero() {
            0.0
        } else {
            self.overlapped_io.as_secs_f64() / total.as_secs_f64()
        }
    }

    pub fn retained_fraction(&self) -> f64 {
        if self.importance_total <= 0.0 {
            1.0
        } else {
            self.importance_kept / self.importance_total
        }
    }

    /// Merge another call's stats (used by aggregating drivers).
    pub fn absorb(&mut self, other: &StageStats) {
        self.io += other.io;
        self.compute += other.compute;
        self.select += other.select;
        self.host += other.host;
        self.bytes_loaded += other.bytes_loaded;
        self.prefetched_bytes += other.prefetched_bytes;
        self.prefetch_hits += other.prefetch_hits;
        self.overlapped_io += other.overlapped_io;
        self.max_inflight = self.max_inflight.max(other.max_inflight);
        self.importance_kept += other.importance_kept;
        self.importance_total += other.importance_total;
    }
}

/// Builder for [`Engine`] — the only way to construct one.
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    model: String,
    profile: DeviceProfile,
    policy: Policy,
    sparsity: f64,
    seed: u64,
    artifact_dir: PathBuf,
    prefetch: bool,
    coalesce: CoalescePolicy,
    exec_threads: usize,
    devices: usize,
    member_profiles: Option<Vec<DeviceProfile>>,
    stripe_policy: StripePolicy,
    stripe_bytes: Option<usize>,
    async_io: bool,
    io_queue_depth: usize,
    backing_dir: Option<PathBuf>,
}

impl EngineBuilder {
    /// Start from a runnable model name ("tiny" | "small" | "base") with
    /// defaults: nano profile, dense policy, prefetch on, contiguous
    /// coalescing, single-threaded kernels, a single-member storage pool
    /// (`NC_DEVICES` overrides the default member count without touching
    /// call sites — CI uses it to run the whole suite sharded),
    /// artifacts in `./artifacts`.
    pub fn new(model: &str) -> Self {
        let devices = std::env::var("NC_DEVICES")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1);
        // `NC_ASYNC_IO=1` flips the default so CI can run the whole test
        // suite through the async pipeline without touching call sites.
        let async_io = std::env::var("NC_ASYNC_IO")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        Self {
            model: model.to_string(),
            profile: DeviceProfile::nano(),
            policy: Policy::Dense,
            sparsity: 0.0,
            seed: 42,
            artifact_dir: PathBuf::from("artifacts"),
            prefetch: true,
            coalesce: CoalescePolicy::contiguous(),
            exec_threads: 1,
            devices,
            member_profiles: None,
            stripe_policy: StripePolicy::RoundRobin,
            stripe_bytes: None,
            async_io,
            io_queue_depth: 2,
            backing_dir: None,
        }
    }

    pub fn policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    /// Fraction of rows *dropped* per matrix, in [0, 1).
    pub fn sparsity(mut self, sparsity: f64) -> Self {
        self.sparsity = sparsity;
        self
    }

    pub fn profile(mut self, profile: DeviceProfile) -> Self {
        self.profile = profile;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn artifacts(mut self, dir: &Path) -> Self {
        self.artifact_dir = dir.to_path_buf();
        self
    }

    /// Enable/disable next-layer prefetch (default on).
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// Override how plans coalesce chunk extents into device commands.
    pub fn coalesce(mut self, policy: CoalescePolicy) -> Self {
        self.coalesce = policy;
        self
    }

    /// Worker-thread count for the executor kernels (default 1 = inline).
    /// Outputs are bit-identical at every value.
    pub fn exec_threads(mut self, threads: usize) -> Self {
        self.exec_threads = threads.max(1);
        self
    }

    /// Number of homogeneous storage-pool members (default 1, or
    /// `NC_DEVICES`), each a [`SimulatedSsd`] with the builder's device
    /// profile over its stripe of the flash image. Homogeneous pools of
    /// any size produce bit-identical outputs and identical
    /// selected-chunk sets — only (virtual) service time changes.
    pub fn devices(mut self, n: usize) -> Self {
        self.devices = n.max(1);
        self.member_profiles = None;
        self
    }

    /// Heterogeneous pool: one member per profile (fast + slow flash mix).
    /// Selection utility then prices chunks under the stripe-weighted
    /// blend of the members' `T[s]` tables.
    pub fn device_profiles(mut self, profiles: Vec<DeviceProfile>) -> Self {
        if !profiles.is_empty() {
            self.devices = profiles.len();
            self.member_profiles = Some(profiles);
        }
        self
    }

    /// How stripe blocks are assigned to members (default round-robin;
    /// [`StripePolicy::HotAware`] co-locates each matrix's hottest rows).
    pub fn stripe_policy(mut self, policy: StripePolicy) -> Self {
        self.stripe_policy = policy;
        self
    }

    /// Explicit stripe-unit size in bytes (default: adaptive per matrix,
    /// `⌈rows / (4·devices)⌉` rows).
    pub fn stripe_bytes(mut self, bytes: usize) -> Self {
        self.stripe_bytes = if bytes == 0 { None } else { Some(bytes) };
        self
    }

    /// Enable the asynchronous I/O pipeline (default off, or
    /// `NC_ASYNC_IO=1`): layer *k+1*'s prefetch is submitted *before*
    /// layer *k*'s kernels run and awaited only when its weights are
    /// consumed. Wall-clock pool members genuinely overlap flash reads
    /// with compute on per-member worker threads; virtual-clock members
    /// are accounted analytically as `max(compute, io)` per stage, so the
    /// latency model stays exact. A pure timing optimization: outputs and
    /// selections are bit-identical with it on or off, at any queue
    /// depth and pool size. Requires prefetch (the default) to have any
    /// effect.
    pub fn async_io(mut self, on: bool) -> Self {
        self.async_io = on;
        self
    }

    /// Bound on in-flight whole-layer prefetches (and on each async I/O
    /// worker's submission queue). Default 2; values are clamped to ≥ 1.
    pub fn io_queue_depth(mut self, depth: usize) -> Self {
        self.io_queue_depth = depth.max(1);
        self
    }

    /// Serve from *real* storage: the flash image is sharded into one
    /// backing file per pool member under `dir` (created if missing,
    /// rewritten on build and on re-calibration) and read through
    /// wall-clock [`crate::storage::RealFileDevice`] members. Selection
    /// still prices chunks with the profiled `T[s]` tables, so outputs
    /// and selections stay bit-identical to the simulated pool. Use a
    /// distinct directory per engine.
    pub fn file_backed(mut self, dir: &Path) -> Self {
        self.backing_dir = Some(dir.to_path_buf());
        self
    }

    /// Build the engine, generating + "flashing" the model weights.
    pub fn build(self) -> Result<Engine> {
        let runtime = XlaRuntime::open(&self.artifact_dir)?;
        let meta = runtime
            .manifest
            .model(&self.model)
            .with_context(|| format!("model {} not in manifest", self.model))?
            .clone();
        let spec = ModelSpec::by_name(&self.model)
            .with_context(|| format!("unknown model {}", self.model))?;
        anyhow::ensure!(spec.runnable, "engine needs a runnable model");
        anyhow::ensure!(
            spec.d == meta.d && spec.h == meta.h && spec.layers == meta.layers,
            "rust spec / python manifest dimension mismatch"
        );
        let store = WeightStore::new(spec.clone(), false, self.seed);
        let member_profiles: Vec<DeviceProfile> = match &self.member_profiles {
            Some(v) if !v.is_empty() => v.clone(),
            _ => vec![self.profile.clone(); self.devices.max(1)],
        };
        let n_dev = member_profiles.len();

        // Profile T[s] once per *distinct* member profile against an
        // unbounded twin (the analytical model is capacity-independent).
        // Sharing one probe seed per profile keeps homogeneous pools of
        // any size on the same table — and therefore on the same
        // selections — as a single device.
        let mut distinct: Vec<(String, LatencyTable)> = Vec::new();
        for p in &member_profiles {
            if distinct.iter().any(|(name, _)| *name == p.name) {
                continue;
            }
            let probe = SimulatedSsd::timing_only(p.clone(), 1 << 40, self.seed ^ 0xBEEF);
            let sat = p.saturation_bytes(0.99);
            let t = Profiler::new(&probe, ProfileConfig::coarse(sat, 1024)).build_table()?;
            distinct.push((p.name.clone(), t));
        }
        let member_tables: Vec<LatencyTable> = member_profiles
            .iter()
            .map(|p| {
                distinct
                    .iter()
                    .find(|(name, _)| *name == p.name)
                    .expect("profiled above")
                    .1
                    .clone()
            })
            .collect();

        // Stripe the flat weight space across the members and blend the
        // member tables into the pool-effective T[s] that selection
        // utility prices chunks with (homogeneous pools reuse the single
        // member table verbatim).
        let stripe =
            StripeLayout::build(&store.layout, n_dev, self.stripe_policy, self.stripe_bytes);
        let table = if distinct.len() == 1 {
            distinct[0].1.clone()
        } else {
            LatencyTable::blended(&member_tables, stripe.device_bytes())
        };
        let pool = build_pool(
            &member_profiles,
            stripe,
            &store.build_image(),
            self.seed ^ 0xD1CE,
            self.backing_dir.as_deref(),
        )?
        .with_tables(member_tables.clone());
        // Wall-clock members get per-member async I/O workers; an
        // all-virtual pool needs none (overlap is credited analytically).
        let async_pipe = (self.async_io && !pool.is_virtual_time())
            .then(|| AsyncIoQueue::start(pool.member_arcs(), self.io_queue_depth));
        let dev_io_names: Vec<String> = (0..n_dev).map(|m| format!("io.dev{m}")).collect();

        // Pre-key the table for every scored row size and pre-render every
        // artifact name; both lookups are on the per-stage hot path and
        // must not allocate there.
        let mut keyed_tables: HashMap<usize, LatencyTable> = HashMap::new();
        for kind in MatrixKind::SCORED {
            let row_bytes = spec.row_bytes(kind);
            keyed_tables
                .entry(row_bytes)
                .or_insert_with(|| table.with_row_bytes(row_bytes));
        }
        let mut artifact_names: HashMap<(&'static str, bool, usize), String> = HashMap::new();
        let mut buckets: Vec<usize> = meta
            .d_buckets
            .iter()
            .chain(meta.h_buckets.iter())
            .copied()
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        for &bucket in &buckets {
            for tt in [meta.t, 1] {
                for base in ["qkv", "gateup", "projres"] {
                    let kind = match (base, tt) {
                        ("qkv", 1) => "qkv_decode".to_string(),
                        ("qkv", _) => "qkv_append".to_string(),
                        (b, 1) => format!("{b}_dec"),
                        (b, _) => b.to_string(),
                    };
                    artifact_names.insert(
                        (base, tt == 1, bucket),
                        Manifest::artifact_name(&kind, &self.model, bucket),
                    );
                }
            }
        }

        let selector = self.policy.selector();
        let core = EngineCore {
            model: self.model,
            policy: self.policy,
            sparsity: self.sparsity,
            seed: self.seed,
            prefetch: self.prefetch,
            async_io: self.async_io,
            io_queue_depth: self.io_queue_depth,
            async_pipe,
            backing_dir: self.backing_dir,
            exec_threads: self.exec_threads,
            runtime,
            meta,
            spec,
            store,
            pool,
            member_profiles,
            member_tables,
            stripe_policy: self.stripe_policy,
            stripe_bytes: self.stripe_bytes,
            dev_io_names,
            table,
            keyed_tables,
            artifact_names,
            planner: IoPlanner::new(self.coalesce),
            selector,
            neuron_cache: None,
            metrics: Mutex::new(Metrics::new()),
            epoch: 0,
        };
        Ok(Engine {
            core: Arc::new(RwLock::new(core)),
        })
    }
}

/// The serving engine facade. `Clone` + `Send` + `Sync`: handles are
/// cheap `Arc` clones and sessions opened from any of them share the
/// flash device, weight store, latency table and planner. Serving takes
/// the core read lock; only re-calibration takes the write lock.
#[derive(Clone)]
pub struct Engine {
    core: Arc<RwLock<EngineCore>>,
}

impl Engine {
    pub fn builder(model: &str) -> EngineBuilder {
        EngineBuilder::new(model)
    }

    /// Open an independent serving session (own KV caches, own prefetch
    /// state, own scratch arena). Sessions must not outlive calibration
    /// epochs silently — they detect re-calibration and reset themselves.
    pub fn new_session(&self) -> Session {
        let core = self.core.read().unwrap();
        let mut state = SessionState::new(&core.spec, core.epoch);
        let mut scratch = ScratchArena::default();
        core.reserve_session_buffers(&mut state, &mut scratch);
        drop(core);
        Session {
            core: self.core.clone(),
            inner: Mutex::new(SessionInner { state, scratch }),
        }
    }

    pub fn spec(&self) -> ModelSpec {
        self.core.read().unwrap().spec.clone()
    }

    pub fn meta(&self) -> ModelMeta {
        self.core.read().unwrap().meta.clone()
    }

    pub fn policy(&self) -> Policy {
        self.core.read().unwrap().policy.clone()
    }

    pub fn latency_table(&self) -> LatencyTable {
        self.core.read().unwrap().table.clone()
    }

    /// Number of storage-pool members serving this engine.
    pub fn devices(&self) -> usize {
        self.core.read().unwrap().pool.len()
    }

    /// Whether the asynchronous I/O pipeline is enabled.
    pub fn async_io(&self) -> bool {
        self.core.read().unwrap().async_io
    }

    /// Configured bound on in-flight whole-layer prefetches.
    pub fn io_queue_depth(&self) -> usize {
        self.core.read().unwrap().io_queue_depth
    }

    /// Snapshot of accumulated per-stage metrics.
    pub fn metrics(&self) -> Metrics {
        self.core.read().unwrap().metrics.lock().unwrap().clone()
    }

    /// Pre-compile all artifacts (avoids first-request compile stalls).
    pub fn warmup(&self) -> Result<usize> {
        let core = self.core.read().unwrap();
        core.runtime.warmup(&core.model)
    }

    /// Run dense calibration passes, build hot–cold permutations per
    /// scored matrix, bake them into the flash layout, and invalidate all
    /// session state. Call before serving (offline step in the paper).
    pub fn calibrate_and_reorder(&self, frames: &[Vec<f32>]) -> Result<()> {
        self.core.write().unwrap().calibrate_and_reorder(frames)
    }

    /// Install a hot-neuron cache built from calibration frequencies.
    pub fn set_neuron_cache(&self, cache: HotNeuronCache) {
        self.core.write().unwrap().neuron_cache = Some(cache);
    }
}

/// Group index within [`MatrixKind::SCORED`] (Q, O, Gate, Down).
fn group_index(kind: MatrixKind) -> usize {
    MatrixKind::SCORED
        .iter()
        .position(|&k| k == kind)
        .expect("scored kind")
}

/// Per-group flash-chunk demand recorded for next-call prefetch. An empty
/// list means "no demand recorded".
type GroupChunks = [Vec<Chunk>; 4];

/// Per-call analytic clock for virtual-pool async accounting. Virtual
/// waits charged to `io` do not advance the real wall clock (nothing
/// actually sleeps), so the stall already charged this call is carried
/// explicitly: the analytic "now" is wall-now plus that stall, the
/// device frees up at the last submission's completion, and each
/// charge is the time remaining from the analytic now — queued reads
/// serialize without double-counting the backlog across stages.
struct VirtualClock {
    /// Analytic completion of the latest virtual submission.
    free_at: Instant,
    /// Virtual stall time already charged to `io` this call.
    stall: Duration,
}

impl VirtualClock {
    fn start() -> Self {
        Self {
            free_at: Instant::now(),
            stall: Duration::ZERO,
        }
    }

    /// The analytic current time: wall clock advanced by charged stalls.
    fn now(&self) -> Instant {
        Instant::now() + self.stall
    }
}

/// Submission state of one layer's in-flight prefetch (async pipeline).
#[derive(Default)]
enum PendingPrefetch {
    /// Nothing submitted for this layer.
    #[default]
    Idle,
    /// Submitted inline against an all-virtual-clock pool: the receipt is
    /// already filled; `completion` places the read's analytic finish on
    /// the wall timeline under a *device-serial* queueing model
    /// (`completion = max(submit, device-free) + service` — concurrent
    /// in-flight reads queue behind each other instead of each crediting
    /// the same compute window), and the overlap credit is settled when
    /// the layer consumes it.
    Virtual { completion: Instant, service: Duration },
    /// Submitted to the async I/O workers (wall-clock pool): the ticket
    /// completes once every member's sub-plan has been read.
    InFlight { ticket: IoTicket },
}

struct SessionState {
    /// KV caches, one per layer.
    kvs: Vec<KvCache>,
    /// Flash chunks each (layer, group) demanded on the previous call —
    /// the prefetch prediction source.
    prev_masks: Vec<GroupChunks>,
    /// This call's demand record; swapped into `prev_masks` at call end.
    next_masks: Vec<GroupChunks>,
    /// Pooled prefetched whole-layer reads, one slot per layer (an empty
    /// plan means "nothing prefetched").
    prefetch: Vec<PlannedRead>,
    /// Async-pipeline submission state, one slot per layer. Every
    /// non-`Idle` entry is consumed at its layer within the same call;
    /// entries only survive a call when it aborted mid-pipeline, and are
    /// drained before the next one begins.
    pending: Vec<PendingPrefetch>,
    epoch: u64,
}

impl SessionState {
    fn new(spec: &ModelSpec, epoch: u64) -> Self {
        Self {
            kvs: (0..spec.layers)
                .map(|_| KvCache::new(spec.cache_slots, spec.d))
                .collect(),
            prev_masks: (0..spec.layers).map(|_| GroupChunks::default()).collect(),
            next_masks: (0..spec.layers).map(|_| GroupChunks::default()).collect(),
            prefetch: (0..spec.layers).map(|_| PlannedRead::default()).collect(),
            pending: (0..spec.layers).map(|_| PendingPrefetch::default()).collect(),
            epoch,
        }
    }

    /// Settle any submission a previous (aborted) call left behind: await
    /// and discard in-flight tickets, clear the matching prefetch slots.
    /// No-op (and allocation-free) when every entry is `Idle`.
    fn drain_stale(&mut self) {
        for (slot, pending) in self.prefetch.iter_mut().zip(self.pending.iter_mut()) {
            match std::mem::take(pending) {
                PendingPrefetch::Idle => {}
                PendingPrefetch::Virtual { .. } => slot.clear(),
                PendingPrefetch::InFlight { ticket } => {
                    ticket.discard();
                    slot.clear();
                }
            }
        }
    }

    fn reset(&mut self, epoch: u64) {
        self.drain_stale();
        for kv in &mut self.kvs {
            kv.clear();
        }
        for masks in self.prev_masks.iter_mut().chain(self.next_masks.iter_mut()) {
            for group in masks.iter_mut() {
                group.clear();
            }
        }
        for slot in &mut self.prefetch {
            slot.clear();
        }
        self.epoch = epoch;
    }
}

/// Everything a session owns and mutates per call: serving state plus the
/// scratch arena all hot-path buffers come from.
struct SessionInner {
    state: SessionState,
    scratch: ScratchArena,
}

/// One serving stream: owns its KV caches, prefetch state, and scratch
/// arena; shares the engine core. `Send + Sync`: concurrent calls on the
/// same session serialize on its internal lock, calls on different
/// sessions run in parallel.
pub struct Session {
    core: Arc<RwLock<EngineCore>>,
    inner: Mutex<SessionInner>,
}

impl Session {
    /// Append one frame of token embeddings (`[T, d]` row-major); returns
    /// the output hidden states and stage stats.
    pub fn append_frame(&self, frame: &[f32]) -> Result<(Vec<f32>, StageStats)> {
        let mut out = Vec::new();
        let stats = self.append_frame_into(frame, &mut out)?;
        Ok((out, stats))
    }

    /// Allocation-free [`Session::append_frame`]: the output hidden states
    /// are written into `out` (cleared + refilled, capacity reused).
    pub fn append_frame_into(&self, frame: &[f32], out: &mut Vec<f32>) -> Result<StageStats> {
        let core = self.core.read().unwrap();
        let mut inner = self.inner.lock().unwrap();
        let t = core.meta.t;
        anyhow::ensure!(
            frame.len() == t * core.meta.d,
            "frame must be [T={}, d={}]",
            t,
            core.meta.d
        );
        let inner = &mut *inner;
        core.forward(&mut inner.state, &mut inner.scratch, frame, t, out)
    }

    /// Decode one token (`[1, d]` embedding).
    pub fn decode_step(&self, token: &[f32]) -> Result<(Vec<f32>, StageStats)> {
        let mut out = Vec::new();
        let stats = self.decode_step_into(token, &mut out)?;
        Ok((out, stats))
    }

    /// Allocation-free [`Session::decode_step`]: the next hidden state is
    /// written into `out` (cleared + refilled, capacity reused). After one
    /// warm-up call, further calls perform no heap allocations.
    pub fn decode_step_into(&self, token: &[f32], out: &mut Vec<f32>) -> Result<StageStats> {
        let core = self.core.read().unwrap();
        let mut inner = self.inner.lock().unwrap();
        anyhow::ensure!(token.len() == core.meta.d, "token must be [d]");
        let inner = &mut *inner;
        if inner.state.epoch == core.epoch {
            anyhow::ensure!(
                !inner.state.kvs.iter().all(|kv| kv.is_empty()),
                "decode requires a non-empty KV cache (append a frame first)"
            );
        } else {
            // The engine was re-calibrated since this session last ran;
            // its KV state is about to be discarded.
            anyhow::bail!("decode requires a non-empty KV cache (append a frame first)");
        }
        core.forward(&mut inner.state, &mut inner.scratch, token, 1, out)
    }

    /// Clear KV caches and prefetch state.
    pub fn reset(&self) {
        let core = self.core.read().unwrap();
        self.inner.lock().unwrap().state.reset(core.epoch);
    }

    /// Total KV tokens currently cached across layers.
    pub fn kv_tokens(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .state
            .kvs
            .iter()
            .map(|kv| kv.len())
            .sum()
    }
}

struct EngineCore {
    model: String,
    policy: Policy,
    sparsity: f64,
    seed: u64,
    prefetch: bool,
    /// Async I/O pipeline enabled (submit-ahead prefetch + completion
    /// tickets). Pure timing change; outputs are invariant.
    async_io: bool,
    /// Bound on in-flight whole-layer prefetches / worker queue slots.
    io_queue_depth: usize,
    /// Per-member I/O workers (wall-clock pools with async I/O only).
    async_pipe: Option<AsyncIoQueue>,
    /// Real-storage backing directory (file-backed pools), if any.
    backing_dir: Option<PathBuf>,
    /// Executor kernel worker count (outputs are thread-count invariant).
    exec_threads: usize,
    runtime: XlaRuntime,
    meta: ModelMeta,
    spec: ModelSpec,
    store: WeightStore,
    /// Sharded storage pool (single-member pools reproduce the legacy
    /// one-device behaviour bit for bit).
    pool: DevicePool,
    /// One profile per pool member (homogeneous = N copies).
    member_profiles: Vec<DeviceProfile>,
    /// Per-member profiled `T[s]` tables.
    member_tables: Vec<LatencyTable>,
    stripe_policy: StripePolicy,
    stripe_bytes: Option<usize>,
    /// Pre-rendered per-member metrics keys ("io.dev0", …).
    dev_io_names: Vec<String>,
    /// Byte-keyed pool-effective latency table (selection utility).
    table: LatencyTable,
    /// The table pre-keyed per scored row size (hot path must not clone).
    keyed_tables: HashMap<usize, LatencyTable>,
    /// Pre-rendered artifact names: (stage base, is_decode, bucket).
    artifact_names: HashMap<(&'static str, bool, usize), String>,
    planner: IoPlanner,
    selector: Option<Box<dyn Selector>>,
    /// Optional hot-neuron cache (§5 memory-budget extension).
    neuron_cache: Option<HotNeuronCache>,
    metrics: Mutex<Metrics>,
    /// Bumped whenever the flash image is rebuilt (re-calibration);
    /// sessions compare and self-reset.
    epoch: u64,
}

impl EngineCore {
    fn calibrate_and_reorder(&mut self, frames: &[Vec<f32>]) -> Result<()> {
        // Collect importance samples with a dense temporary pass.
        let mut samples: HashMap<(usize, MatrixKind), Vec<Vec<f32>>> = HashMap::new();
        for f in frames {
            let collected = self.forward_collect(f)?;
            for (key, imp) in collected {
                samples.entry(key).or_default().push(imp);
            }
        }
        // Build + install permutations, then rebuild the flash image.
        for layer in 0..self.spec.layers {
            for kind in MatrixKind::SCORED {
                let rows = self.spec.shape_of(kind).rows;
                if let Some(s) = samples.get(&(layer, kind)) {
                    let perm = HotColdReorder.build(s, rows);
                    for member in MatrixKind::ALL {
                        if member.mask_source() == kind {
                            self.store
                                .set_permutation(MatrixId::new(layer, member), perm.clone());
                        }
                    }
                }
            }
        }
        let stripe = StripeLayout::build(
            &self.store.layout,
            self.member_profiles.len(),
            self.stripe_policy,
            self.stripe_bytes,
        );
        self.pool = build_pool(
            &self.member_profiles,
            stripe,
            &self.store.build_image(),
            self.seed ^ 0xD1CE,
            self.backing_dir.as_deref(),
        )?
        .with_tables(self.member_tables.clone());
        // The old workers held handles to the replaced members; restart
        // them against the rebuilt pool.
        self.async_pipe = (self.async_io && !self.pool.is_virtual_time())
            .then(|| AsyncIoQueue::start(self.pool.member_arcs(), self.io_queue_depth));
        self.epoch += 1;
        Ok(())
    }

    /// Dense forward that records per-(layer, scored-kind) importance —
    /// the calibration pass. Does not touch KV caches.
    fn forward_collect(&self, frame: &[f32]) -> Result<Vec<((usize, MatrixKind), Vec<f32>)>> {
        let t = self.meta.t;
        let d = self.meta.d;
        anyhow::ensure!(frame.len() == t * d, "frame must be [T, d]");
        let mut out = Vec::new();
        let mut x = frame.to_vec();
        let empty_k = KvCache::new(self.spec.cache_slots, d);
        for layer in 0..self.spec.layers {
            let hn = rmsnorm(&x, t, d);
            out.push(((layer, MatrixKind::Q), col_importance(&hn, t, d)));
            // Dense stage executions (full buckets, identity gather).
            let (attn, _k, _v) = self.exec_qkv(layer, &hn, t, &empty_k, &full_mask(d))?;
            out.push(((layer, MatrixKind::O), col_importance(&attn, t, d)));
            let x1 = self.exec_projres(layer, MatrixKind::O, &attn, t, &x, &full_mask(d))?;
            let hn2 = rmsnorm(&x1, t, d);
            out.push(((layer, MatrixKind::Gate), col_importance(&hn2, t, d)));
            let act = self.exec_gateup(layer, &hn2, t, &full_mask(d))?;
            let h = self.meta.h;
            out.push(((layer, MatrixKind::Down), col_importance(&act, t, h)));
            x = self.exec_projres(layer, MatrixKind::Down, &act, t, &x1, &full_mask(h))?;
        }
        Ok(out)
    }

    /// One serving call (frame append or decode step). `&self`: all
    /// mutable state lives in the session (`state` + `scratch`), so
    /// concurrent sessions proceed under the shared read lock.
    fn forward(
        &self,
        state: &mut SessionState,
        scratch: &mut ScratchArena,
        input: &[f32],
        t: usize,
        out: &mut Vec<f32>,
    ) -> Result<StageStats> {
        if state.epoch != self.epoch {
            state.reset(self.epoch);
        }
        let d = self.meta.d;
        let h = self.meta.h;
        let c = self.spec.cache_slots;
        let layers = self.spec.layers;
        let mut stats = StageStats::default();
        let mut prefetch_service = Duration::ZERO;

        let sc = &mut *scratch;
        sc.pool.accum.reset(self.pool.len());
        sc.fwd.xa.clear();
        sc.fwd.xa.extend_from_slice(input);

        // Async pipeline state: keep up to `io_queue_depth` whole-layer
        // prefetches in flight, each submitted *before* the kernels of
        // the layers it overlaps with run, and awaited only at the moment
        // its layer consumes the weights.
        let async_on = self.async_io && self.prefetch;
        let depth = self.io_queue_depth.max(1);
        let mut in_flight = 0u64;
        let mut next_submit = 1usize;
        // Per-call analytic clock for the virtual-pool queueing model
        // (virtual-clock pools only; wall-clock pools measure real time).
        let mut vclock = VirtualClock::start();
        if async_on {
            state.drain_stale();
        }

        for layer in 0..layers {
            let layer_t0 = Instant::now();
            if async_on {
                // Await this layer's prefetch (if one is in flight) right
                // before its weights are consumed; only service time the
                // intervening compute could not hide is charged.
                in_flight -= self.consume_pending(
                    state,
                    sc,
                    layer,
                    &mut stats,
                    &mut prefetch_service,
                    &mut vclock,
                )?;
                // Then top up the submission window before this layer's
                // kernels execute. Consuming first keeps the bound exact:
                // at most `depth` layers are ever in flight per session,
                // so a submission never blocks on a full member queue
                // ahead of this layer's compute (the queues carry slack
                // for several concurrent sessions; past that, a full
                // queue is deliberate backpressure).
                while next_submit < layers && next_submit <= layer + depth {
                    let l = next_submit;
                    next_submit += 1;
                    if self.submit_prefetch(state, sc, l, &mut stats, &mut vclock)? {
                        in_flight += 1;
                        stats.max_inflight = stats.max_inflight.max(in_flight);
                    }
                }
            }
            // Whole-layer prefetch buffer for this layer, if the previous
            // call's masks were submitted while layer-1 executed. Swap the
            // pooled slot out (its buffers cycle back in on the next
            // prefetch write) and leave the slot empty.
            std::mem::swap(&mut sc.pre, &mut state.prefetch[layer]);
            state.prefetch[layer].clear();
            let pre = if sc.pre.is_empty() { None } else { Some(&sc.pre) };

            // --- qkv + attention ---
            let timer = StageTimer::start();
            rmsnorm_into(&sc.fwd.xa, t, d, &mut sc.fwd.hn);
            col_importance_into(&sc.fwd.hn, t, d, &mut sc.fwd.imp);
            stats.host += timer.finish();
            self.select_into(
                layer,
                MatrixKind::Q,
                &sc.fwd.imp,
                &mut stats,
                &mut sc.sel_scratch,
                &mut sc.imp_phys,
                &mut sc.sel,
            );
            let bucket = self.load_group(
                layer,
                MatrixKind::Q,
                &sc.fwd.hn,
                t,
                &sc.sel,
                pre,
                &mut sc.gather,
                &mut sc.plan_scratch,
                &mut sc.pool,
                &mut stats,
            )?;
            let dst = &mut state.next_masks[layer][group_index(MatrixKind::Q)];
            dst.clear();
            dst.extend_from_slice(&sc.gather.flash_chunks);
            {
                let timer = StageTimer::start();
                let (kc, vc, kmask) = state.kvs[layer].views();
                let name = self.artifact_name("qkv", t, bucket)?;
                let inputs = [
                    TensorView::mat(t, bucket, &sc.gather.xs),
                    TensorView::mat(bucket, d, &sc.gather.weights[0]),
                    TensorView::mat(bucket, d, &sc.gather.weights[1]),
                    TensorView::mat(bucket, d, &sc.gather.weights[2]),
                    TensorView::mat(c, d, kc),
                    TensorView::mat(c, d, vc),
                    TensorView::vec1(c, kmask),
                ];
                self.runtime
                    .execute_into(name, &inputs, self.exec_threads, &mut sc.exec, &mut sc.outs)?;
                stats.compute += timer.finish();
            }
            std::mem::swap(&mut sc.fwd.attn, &mut sc.outs.out[0]);
            state.kvs[layer].append(&sc.outs.out[1], &sc.outs.out[2]);

            // --- o projection + residual ---
            let timer = StageTimer::start();
            col_importance_into(&sc.fwd.attn, t, d, &mut sc.fwd.imp);
            stats.host += timer.finish();
            self.select_into(
                layer,
                MatrixKind::O,
                &sc.fwd.imp,
                &mut stats,
                &mut sc.sel_scratch,
                &mut sc.imp_phys,
                &mut sc.sel,
            );
            let bucket = self.load_group(
                layer,
                MatrixKind::O,
                &sc.fwd.attn,
                t,
                &sc.sel,
                pre,
                &mut sc.gather,
                &mut sc.plan_scratch,
                &mut sc.pool,
                &mut stats,
            )?;
            let dst = &mut state.next_masks[layer][group_index(MatrixKind::O)];
            dst.clear();
            dst.extend_from_slice(&sc.gather.flash_chunks);
            {
                let timer = StageTimer::start();
                let name = self.artifact_name("projres", t, bucket)?;
                let inputs = [
                    TensorView::mat(t, bucket, &sc.gather.xs),
                    TensorView::mat(bucket, d, &sc.gather.weights[0]),
                    TensorView::mat(t, d, &sc.fwd.xa),
                ];
                self.runtime
                    .execute_into(name, &inputs, self.exec_threads, &mut sc.exec, &mut sc.outs)?;
                stats.compute += timer.finish();
            }
            std::mem::swap(&mut sc.fwd.xb, &mut sc.outs.out[0]);

            // --- gate/up (SwiGLU) ---
            let timer = StageTimer::start();
            rmsnorm_into(&sc.fwd.xb, t, d, &mut sc.fwd.hn);
            col_importance_into(&sc.fwd.hn, t, d, &mut sc.fwd.imp);
            stats.host += timer.finish();
            self.select_into(
                layer,
                MatrixKind::Gate,
                &sc.fwd.imp,
                &mut stats,
                &mut sc.sel_scratch,
                &mut sc.imp_phys,
                &mut sc.sel,
            );
            let bucket = self.load_group(
                layer,
                MatrixKind::Gate,
                &sc.fwd.hn,
                t,
                &sc.sel,
                pre,
                &mut sc.gather,
                &mut sc.plan_scratch,
                &mut sc.pool,
                &mut stats,
            )?;
            let dst = &mut state.next_masks[layer][group_index(MatrixKind::Gate)];
            dst.clear();
            dst.extend_from_slice(&sc.gather.flash_chunks);
            {
                let timer = StageTimer::start();
                let name = self.artifact_name("gateup", t, bucket)?;
                let inputs = [
                    TensorView::mat(t, bucket, &sc.gather.xs),
                    TensorView::mat(bucket, h, &sc.gather.weights[0]),
                    TensorView::mat(bucket, h, &sc.gather.weights[1]),
                ];
                self.runtime
                    .execute_into(name, &inputs, self.exec_threads, &mut sc.exec, &mut sc.outs)?;
                stats.compute += timer.finish();
            }
            std::mem::swap(&mut sc.fwd.act, &mut sc.outs.out[0]);

            // --- down projection + residual ---
            let timer = StageTimer::start();
            col_importance_into(&sc.fwd.act, t, h, &mut sc.fwd.imp);
            stats.host += timer.finish();
            self.select_into(
                layer,
                MatrixKind::Down,
                &sc.fwd.imp,
                &mut stats,
                &mut sc.sel_scratch,
                &mut sc.imp_phys,
                &mut sc.sel,
            );
            let bucket = self.load_group(
                layer,
                MatrixKind::Down,
                &sc.fwd.act,
                t,
                &sc.sel,
                pre,
                &mut sc.gather,
                &mut sc.plan_scratch,
                &mut sc.pool,
                &mut stats,
            )?;
            let dst = &mut state.next_masks[layer][group_index(MatrixKind::Down)];
            dst.clear();
            dst.extend_from_slice(&sc.gather.flash_chunks);
            {
                let timer = StageTimer::start();
                let name = self.artifact_name("projres", t, bucket)?;
                let inputs = [
                    TensorView::mat(t, bucket, &sc.gather.xs),
                    TensorView::mat(bucket, d, &sc.gather.weights[0]),
                    TensorView::mat(t, d, &sc.fwd.xb),
                ];
                self.runtime
                    .execute_into(name, &inputs, self.exec_threads, &mut sc.exec, &mut sc.outs)?;
                stats.compute += timer.finish();
            }
            std::mem::swap(&mut sc.fwd.xa, &mut sc.outs.out[0]);

            // --- double-buffered prefetch of layer l+1 (sync mode) ---
            // Submit the next layer's predicted whole-layer read now; the
            // service time it cannot hide behind this layer's compute is
            // what the caller pays. (The async pipeline replaces this
            // with submit-ahead at layer start + await-at-consumption.)
            if !async_on && self.prefetch && layer + 1 < layers {
                prefetch_service += self.prefetch_layer(
                    state,
                    &mut sc.plan_scratch,
                    &mut sc.pool,
                    layer + 1,
                    layer_t0.elapsed(),
                    &mut stats,
                )?;
            }
        }
        std::mem::swap(&mut state.prev_masks, &mut state.next_masks);
        // One metrics fold per call (not per stage): the shared mutex is
        // touched once, so concurrent sessions don't serialize on it.
        {
            let mut metrics = self.metrics.lock().unwrap();
            metrics.add("host", stats.host);
            metrics.add("select", stats.select);
            metrics.add("compute", stats.compute);
            metrics.add("io", stats.io);
            if prefetch_service > Duration::ZERO {
                metrics.add("prefetch", prefetch_service);
                // Service time the pipeline hid behind compute; the
                // overlap ratio is `io.overlapped / (io + io.overlapped)`.
                metrics.add("io.overlapped", stats.overlapped_io);
            }
            if async_on {
                // Per-call max of in-flight whole-layer prefetches
                // (accumulated; divide by the "io" call count for the
                // average achieved queue depth).
                metrics.add_bytes("io.queue_depth", stats.max_inflight);
            }
            metrics.add_bytes("io", stats.bytes_loaded);
            // Per-member I/O accounting (multi-member pools only): bytes
            // and summed service per device, from which utilization skew
            // is derived. Keys are pre-rendered, so this allocates
            // nothing at steady state.
            if self.pool.len() > 1 {
                for m in 0..self.pool.len() {
                    metrics.add(&self.dev_io_names[m], sc.pool.accum.service[m]);
                    metrics.add_bytes(&self.dev_io_names[m], sc.pool.accum.bytes[m]);
                }
            }
        }
        out.clear();
        out.extend_from_slice(&sc.fwd.xa);
        Ok(stats)
    }

    /// Plan the predicted flash demand of `layer` (all four selection
    /// groups, every member matrix — one cross-matrix command batch) into
    /// the session's pooled prefetch slot. Returns whether the plan is
    /// non-empty. Allocation-free.
    fn plan_layer_prefetch(
        &self,
        state: &mut SessionState,
        plan_scratch: &mut PlanScratch,
        layer: usize,
    ) -> bool {
        let SessionState {
            prev_masks,
            prefetch,
            ..
        } = state;
        let Some(groups) = prev_masks.get(layer) else {
            return false;
        };
        // At most the seven matrices of one layer; stack-allocated.
        let empty: &[Chunk] = &[];
        let mut requests: [(MatrixId, &[Chunk]); 7] =
            [(MatrixId::new(layer, MatrixKind::Q), empty); 7];
        let mut n = 0usize;
        for (gi, scored) in MatrixKind::SCORED.into_iter().enumerate() {
            let chunks = &groups[gi];
            if chunks.is_empty() {
                continue;
            }
            for member in MatrixKind::ALL {
                if member.mask_source() == scored {
                    requests[n] = (MatrixId::new(layer, member), chunks.as_slice());
                    n += 1;
                }
            }
        }
        if n == 0 {
            return false;
        }
        let slot = &mut prefetch[layer];
        self.planner.plan_refs_into(
            &self.store.layout,
            &requests[..n],
            Some(&self.table),
            plan_scratch,
            &mut slot.plan,
        );
        !slot.plan.is_empty()
    }

    /// Synchronous-mode prefetch: plan + submit `layer`'s predicted
    /// demand into its slot. `overlap` is the wall-clock compute window
    /// already elapsed that the prefetch hides behind. Returns the raw
    /// (pre-overlap-credit) service time for the caller's metrics fold.
    fn prefetch_layer(
        &self,
        state: &mut SessionState,
        plan_scratch: &mut PlanScratch,
        pool_scratch: &mut PoolScratch,
        layer: usize,
        overlap: Duration,
        stats: &mut StageStats,
    ) -> Result<Duration> {
        if !self.plan_layer_prefetch(state, plan_scratch, layer) {
            return Ok(Duration::ZERO);
        }
        let PlannedRead { plan, receipt } = &mut state.prefetch[layer];
        if let Err(e) = self.submit_pooled(plan, pool_scratch, receipt) {
            // A failed submission must not leave a non-empty plan over an
            // unfilled receipt: the next call would swap the slot in as a
            // valid prefetch and serve garbage bytes.
            state.prefetch[layer].clear();
            return Err(e);
        }
        let PlannedRead { plan, receipt } = &mut state.prefetch[layer];
        let service = receipt.service;
        let charged = service.saturating_sub(overlap);
        stats.io += charged;
        stats.overlapped_io += service - charged;
        stats.bytes_loaded += plan.payload_bytes();
        stats.prefetched_bytes += plan.payload_bytes();
        Ok(service)
    }

    /// Async-pipeline submission of `layer`'s predicted prefetch demand.
    /// Returns whether anything was submitted (and is now in flight).
    ///
    /// Virtual-clock pools submit inline (an analytical clock cannot
    /// observe concurrency — the data and service time are exact either
    /// way) and place the read's analytic completion on the wall
    /// timeline under the device-serial queueing model of
    /// [`VirtualClock`]; the overlap credit is settled in
    /// [`EngineCore::consume_pending`]. Wall-clock pools hand the
    /// sharded plan to the per-member I/O workers and hold the
    /// completion ticket.
    fn submit_prefetch(
        &self,
        state: &mut SessionState,
        sc: &mut ScratchArena,
        layer: usize,
        stats: &mut StageStats,
        vclock: &mut VirtualClock,
    ) -> Result<bool> {
        if !self.plan_layer_prefetch(state, &mut sc.plan_scratch, layer) {
            return Ok(false);
        }
        let SessionState {
            prefetch, pending, ..
        } = state;
        let PlannedRead { plan, receipt } = &mut prefetch[layer];
        stats.bytes_loaded += plan.payload_bytes();
        stats.prefetched_bytes += plan.payload_bytes();
        match &self.async_pipe {
            None => {
                if let Err(e) = self.submit_pooled(plan, &mut sc.pool, receipt) {
                    // Never leave a non-empty plan over an unfilled
                    // receipt: the next call would swap the slot in as a
                    // valid prefetch and serve garbage bytes.
                    prefetch[layer].clear();
                    return Err(e);
                }
                let service = prefetch[layer].receipt.service;
                // Device-serial virtual queueing: this read starts when
                // the (pool-level) virtual device frees up, never before
                // the analytic now — concurrent in-flight prefetches
                // must not each credit the same compute window.
                let start = vclock.free_at.max(vclock.now());
                let completion = start + service;
                vclock.free_at = completion;
                pending[layer] = PendingPrefetch::Virtual {
                    completion,
                    service,
                };
            }
            Some(pipe) => {
                self.planner
                    .shard_into(plan, self.pool.stripe(), &mut sc.pool.sharded);
                // Pre-size the logical receipt here; the workers fill
                // their own staging buffers and the ticket scatters into
                // these bytes at await time.
                let total = receipt.presize_for(plan.cmds());
                if sc.pool.sharded.total_bytes() != total {
                    let covered = sc.pool.sharded.total_bytes();
                    prefetch[layer].clear();
                    anyhow::bail!("sharded prefetch covers {covered} of {total} plan bytes");
                }
                let ticket = pipe.submit(&sc.pool.sharded);
                pending[layer] = PendingPrefetch::InFlight { ticket };
            }
        }
        Ok(true)
    }

    /// Settle `layer`'s in-flight prefetch right before its weights are
    /// consumed. Returns 1 if a submission was pending (the caller's
    /// in-flight counter decrements), 0 otherwise.
    ///
    /// Accounting charges only what compute could not hide: for virtual
    /// clocks, the time remaining until the read's device-serial
    /// analytic completion — the stage pays `max(compute, io)` with
    /// queued reads serializing on the virtual device (a single pool
    /// cannot serve N in-flight layers at N× bandwidth); for wall-clock
    /// tickets, the time this call actually blocked waiting. The hidden
    /// remainder lands in `overlapped_io`.
    #[allow(clippy::too_many_arguments)]
    fn consume_pending(
        &self,
        state: &mut SessionState,
        sc: &mut ScratchArena,
        layer: usize,
        stats: &mut StageStats,
        prefetch_service: &mut Duration,
        vclock: &mut VirtualClock,
    ) -> Result<u64> {
        match std::mem::take(&mut state.pending[layer]) {
            PendingPrefetch::Idle => Ok(0),
            PendingPrefetch::Virtual {
                completion,
                service,
            } => {
                // Remaining time until the device-serial analytic finish,
                // measured from the analytic now (wall clock + stalls
                // already charged this call, which nothing actually slept
                // through).
                let charged = completion.saturating_duration_since(vclock.now());
                vclock.stall += charged;
                stats.io += charged;
                stats.overlapped_io += service.saturating_sub(charged);
                *prefetch_service += service;
                Ok(1)
            }
            PendingPrefetch::InFlight { ticket } => {
                let slot = &mut state.prefetch[layer];
                sc.pool.last.reset(self.pool.len());
                let wait_t0 = Instant::now();
                let waited = ticket.wait_scatter(&mut slot.receipt.bytes, &mut sc.pool.last);
                let service = match waited {
                    Ok(d) => d,
                    Err(e) => {
                        slot.clear();
                        return Err(e);
                    }
                };
                let blocked = wait_t0.elapsed();
                slot.receipt.service = service;
                sc.pool.accum.absorb(&sc.pool.last);
                stats.io += blocked;
                stats.overlapped_io += service.saturating_sub(blocked);
                *prefetch_service += service;
                Ok(1)
            }
        }
    }

    /// Submit one logical plan through the storage pool. Single-member
    /// pools delegate straight to the member (bit-identical to the
    /// historical one-device path); larger pools run the
    /// [`IoPlanner::shard_into`] step and fan the sub-plans out across
    /// members, reassembling the logical receipt. Per-member
    /// bytes/service land in `ps.last` and accumulate into `ps.accum`
    /// for the per-call metrics fold. Allocation-free at steady state.
    fn submit_pooled(
        &self,
        plan: &ReadPlan,
        ps: &mut PoolScratch,
        receipt: &mut PlanReceipt,
    ) -> Result<()> {
        if self.pool.len() == 1 {
            self.pool.member(0).submit_into(plan, receipt)?;
            ps.last.reset(1);
            ps.last.bytes[0] = plan.cmd_bytes();
            ps.last.service[0] = receipt.service;
        } else {
            self.planner.shard_into(plan, self.pool.stripe(), &mut ps.sharded);
            self.pool.submit_sharded_into(
                plan,
                &ps.sharded,
                &mut ps.staging,
                receipt,
                &mut ps.last,
            )?;
        }
        ps.accum.absorb(&ps.last);
        Ok(())
    }

    /// Run the selection policy for one scored matrix, writing the mask
    /// into `out` (arena-backed; no allocations at steady state).
    #[allow(clippy::too_many_arguments)]
    fn select_into(
        &self,
        layer: usize,
        kind: MatrixKind,
        importance_logical: &[f32],
        stats: &mut StageStats,
        scratch: &mut SelectScratch,
        imp_phys: &mut Vec<f32>,
        out: &mut SelectionMask,
    ) {
        let rows = importance_logical.len();
        let timer = StageTimer::start();
        // Move importance into physical (reordered) row space.
        let id = MatrixId::new(layer, kind);
        match self.store.permutation(id) {
            Some(p) => p.apply_into(importance_logical, imp_phys),
            None => {
                imp_phys.clear();
                imp_phys.extend_from_slice(importance_logical);
            }
        }
        let total: f64 = imp_phys.iter().map(|&v| v as f64).sum();
        // Cached rows are free: zero their importance pre-selection (§5).
        if let Some(cache) = &self.neuron_cache {
            cache.zero_cached(id, imp_phys);
        }
        let budget = ((1.0 - self.sparsity) * rows as f64).round() as usize;
        match &self.selector {
            None => out.set_full(rows),
            Some(s) => {
                let row_bytes = self.spec.row_bytes(kind);
                let table = self
                    .keyed_tables
                    .get(&row_bytes)
                    .expect("table pre-keyed for every scored row size");
                s.select_into(imp_phys, budget, table, scratch, out);
            }
        }
        stats.select += timer.finish();
        stats.importance_total += total;
        stats.importance_kept += out.captured_importance(imp_phys);
        if let Some(cache) = &self.neuron_cache {
            stats.importance_kept +=
                cache.cached_importance(id, importance_logical, self.store.permutation(id));
        }
    }

    /// Load all matrices of the selection group led by `kind`, gather the
    /// activations, pad to the compiled bucket. One planned, cross-matrix
    /// flash submission serves every member; rows already resident in the
    /// layer prefetch buffer or the hot-neuron cache are not re-read.
    ///
    /// Staging lands in the arena: `g.xs` (gathered activations),
    /// `g.weights[..members]` (weight buckets the executor reads in
    /// place), `g.flash_chunks` (demand recorded for prefetch). Returns
    /// the compiled bucket size.
    #[allow(clippy::too_many_arguments)]
    fn load_group(
        &self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        sel: &SelectionMask,
        prefetched: Option<&PlannedRead>,
        g: &mut crate::coordinator::arena::GatherScratch,
        plan_scratch: &mut PlanScratch,
        pool_scratch: &mut PoolScratch,
        stats: &mut StageStats,
    ) -> Result<usize> {
        let members: &'static [MatrixKind] = match kind {
            MatrixKind::Q => &[MatrixKind::Q, MatrixKind::K, MatrixKind::V],
            MatrixKind::O => &[MatrixKind::O],
            MatrixKind::Gate => &[MatrixKind::Gate, MatrixKind::Up],
            MatrixKind::Down => &[MatrixKind::Down],
            _ => unreachable!("only scored kinds lead a group"),
        };
        let in_rows = self.spec.shape_of(kind).rows;

        // Union of selected + cached rows (sorted, physical space).
        let id0 = MatrixId::new(layer, kind);
        g.phys_rows.clear();
        for chunk in &sel.chunks {
            g.phys_rows.extend(chunk.start..chunk.end());
        }
        g.flash_chunks.clear();
        g.flash_chunks.extend_from_slice(&sel.chunks);
        if let Some(cache) = &self.neuron_cache {
            let cached = cache.cached_rows(id0);
            if !cached.is_empty() {
                g.selset.clear();
                g.selset.resize(in_rows, false);
                for &r in g.phys_rows.iter() {
                    g.selset[r] = true;
                }
                for &r in cached {
                    if !g.selset[r] {
                        g.phys_rows.push(r);
                    }
                }
                g.phys_rows.sort_unstable();
                // Flash reads exclude cached rows.
                g.flash_chunks.clear();
                for chunk in &sel.chunks {
                    g.flash_chunks.extend(cache.subtract_cached(id0, *chunk));
                }
            }
        }

        let buckets = if kind == MatrixKind::Down {
            &self.meta.h_buckets
        } else {
            &self.meta.d_buckets
        };
        let bucket = ModelMeta::bucket_for(buckets, g.phys_rows.len());

        // Gather activations: xs[:, j] = acts[:, logical(phys_rows[j])].
        let timer = StageTimer::start();
        let perm = self.store.permutation(id0);
        g.xs.clear();
        g.xs.resize(t * bucket, 0.0);
        for (j, &p) in g.phys_rows.iter().enumerate() {
            let logical = perm.map(|pm| pm.old_of(p)).unwrap_or(p);
            for ti in 0..t {
                g.xs[ti * bucket + j] = acts[ti * in_rows + logical];
            }
        }
        stats.host += timer.finish();

        // Rows the prefetch buffer already holds need no fresh read; the
        // residual demand is planned as one cross-matrix batch. Coverage is
        // identical across members (the prefetcher requested the same
        // chunks for each), so the lead member's cursor decides.
        g.residual.clear();
        match prefetched {
            None => g.residual.extend_from_slice(&g.flash_chunks),
            Some(pre) => {
                let lead = MatrixId::new(layer, members[0]);
                let mut cursor = RowCursor::new(pre, lead);
                for chunk in &g.flash_chunks {
                    let mut run: Option<usize> = None;
                    for r in chunk.start..chunk.end() {
                        if cursor.advance_to(r).is_some() {
                            if let Some(s) = run.take() {
                                g.residual.push(Chunk::new(s, r - s));
                            }
                        } else if run.is_none() {
                            run = Some(r);
                        }
                    }
                    if let Some(s) = run {
                        g.residual.push(Chunk::new(s, chunk.end() - s));
                    }
                }
            }
        }

        // One planned submission for every member's residual rows.
        let empty: &[Chunk] = &[];
        let mut requests: [(MatrixId, &[Chunk]); 3] = [(id0, empty); 3];
        for (i, member) in members.iter().enumerate() {
            requests[i] = (MatrixId::new(layer, *member), g.residual.as_slice());
        }
        self.planner.plan_refs_into(
            &self.store.layout,
            &requests[..members.len()],
            Some(&self.table),
            plan_scratch,
            &mut g.fresh.plan,
        );
        let have_fresh = !g.fresh.plan.is_empty();
        if have_fresh {
            self.submit_pooled(&g.fresh.plan, pool_scratch, &mut g.fresh.receipt)?;
            stats.bytes_loaded += g.fresh.plan.payload_bytes();
        } else {
            g.fresh.receipt.clear();
        }
        let io_total = g.fresh.receipt.service;

        // Assemble per-member weight buckets: fresh read → prefetch buffer
        // → hot-neuron cache, walking phys_rows in ascending order. The
        // executor reads these buffers in place (no clones).
        let timer = StageTimer::start();
        for (mi, member) in members.iter().enumerate() {
            let id = MatrixId::new(layer, *member);
            let cols = self.spec.shape_of(*member).cols;
            let w = &mut g.weights[mi];
            w.clear();
            w.resize(bucket * cols, 0.0);
            let mut fresh_cursor = if have_fresh {
                Some(RowCursor::new(&g.fresh, id))
            } else {
                None
            };
            let mut pre_cursor = prefetched.map(|p| RowCursor::new(p, id));
            for (j, &p) in g.phys_rows.iter().enumerate() {
                let dst = &mut w[j * cols..(j + 1) * cols];
                if let Some(bytes) = fresh_cursor.as_mut().and_then(|cur| cur.advance_to(p)) {
                    decode_f32_into(bytes, dst);
                    continue;
                }
                if let Some(bytes) = pre_cursor.as_mut().and_then(|cur| cur.advance_to(p)) {
                    decode_f32_into(bytes, dst);
                    stats.prefetch_hits += 1;
                    continue;
                }
                if let Some(cache) = &self.neuron_cache {
                    if let Some(row) = cache.row_data(id, p) {
                        dst.copy_from_slice(row);
                    }
                }
            }
        }
        stats.host += timer.finish();

        stats.io += io_total;
        Ok(bucket)
    }

    /// Dense helpers used by the calibration pass. These also flow through
    /// the planned-submit path (via [`WeightStore::read_rows`]).
    fn exec_qkv(
        &self,
        layer: usize,
        hn: &[f32],
        t: usize,
        kv: &KvCache,
        sel: &SelectionMask,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = self.meta.d;
        let load = |m: MatrixKind| -> Result<Vec<f32>> {
            let id = MatrixId::new(layer, m);
            let (rows, _) = self.store.read_rows(&self.pool, id, &sel.chunks)?;
            Ok(rows)
        };
        let (kc, vc, mask) = kv.tensors();
        let name = self.artifact_name("qkv", t, d)?;
        let out = self.runtime.execute(
            name,
            &[
                Tensor::new(vec![t, d], hn.to_vec()),
                Tensor::new(vec![d, d], load(MatrixKind::Q)?),
                Tensor::new(vec![d, d], load(MatrixKind::K)?),
                Tensor::new(vec![d, d], load(MatrixKind::V)?),
                kc,
                vc,
                mask,
            ],
        )?;
        Ok((out[0].data.clone(), out[1].data.clone(), out[2].data.clone()))
    }

    fn exec_gateup(&self, layer: usize, hn: &[f32], t: usize, sel: &SelectionMask) -> Result<Vec<f32>> {
        let d = self.meta.d;
        let h = self.meta.h;
        let gate = self
            .store
            .read_rows(&self.pool, MatrixId::new(layer, MatrixKind::Gate), &sel.chunks)?
            .0;
        let up = self
            .store
            .read_rows(&self.pool, MatrixId::new(layer, MatrixKind::Up), &sel.chunks)?
            .0;
        let name = self.artifact_name("gateup", t, d)?;
        let out = self.runtime.execute(
            name,
            &[
                Tensor::new(vec![t, d], hn.to_vec()),
                Tensor::new(vec![d, h], gate),
                Tensor::new(vec![d, h], up),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    fn exec_projres(
        &self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        residual: &[f32],
        sel: &SelectionMask,
    ) -> Result<Vec<f32>> {
        let d = self.meta.d;
        let rows = self.spec.shape_of(kind).rows;
        let w = self
            .store
            .read_rows(&self.pool, MatrixId::new(layer, kind), &sel.chunks)?
            .0;
        let name = self.artifact_name("projres", t, rows)?;
        let out = self.runtime.execute(
            name,
            &[
                Tensor::new(vec![t, rows], acts.to_vec()),
                Tensor::new(vec![rows, d], w),
                Tensor::new(vec![t, d], residual.to_vec()),
            ],
        )?;
        Ok(out[0].data.clone())
    }

    /// Pre-reserve worst-case capacities for every session buffer whose
    /// length depends on selection *shape*: selections drift token to
    /// token as activations evolve, so the warm-up call alone cannot
    /// bound chunk-count-dependent vectors. Capacities are capped by the
    /// selection budget plus any hot-neuron-cache rows installed at
    /// session-open time (cached rows join the compute set on top of the
    /// budget), so this reserves the sparse working set, not the dense
    /// one. A cache installed *after* a session opens can still grow that
    /// session's gather buffers once (amortized, not steady-state). The
    /// allocation-regression test relies on this.
    fn reserve_session_buffers(&self, state: &mut SessionState, scratch: &mut ScratchArena) {
        let spec = &self.spec;
        let t_max = self.meta.t;
        let n_max = spec.d.max(spec.h);
        let max_chunks = n_max / 2 + 1;
        let keep = (1.0 - self.sparsity).clamp(0.0, 1.0);
        let kept_rows = |rows: usize| (((keep * rows as f64).round() as usize).max(1)).min(rows);
        // Worst case cached rows joining a group's compute set (any layer).
        let cached_max = |kind: MatrixKind| -> usize {
            self.neuron_cache.as_ref().map_or(0, |cache| {
                (0..spec.layers)
                    .map(|layer| cache.cached_rows(MatrixId::new(layer, kind)).len())
                    .max()
                    .unwrap_or(0)
            })
        };
        let mut group_bytes_max = 0usize;
        let mut layer_bytes = 0usize;
        let mut xs_cap = 0usize;
        let mut w_cap = 0usize;
        for kind in MatrixKind::SCORED {
            let rows = spec.shape_of(kind).rows;
            // Flash payload is budget-capped (cached rows are never
            // re-read); the gathered compute set adds cached rows.
            let kept_io = kept_rows(rows);
            let kept_compute = (kept_io + cached_max(kind)).min(rows);
            let buckets = if kind == MatrixKind::Down {
                &self.meta.h_buckets
            } else {
                &self.meta.d_buckets
            };
            let bucket = ModelMeta::bucket_for(buckets, kept_compute);
            xs_cap = xs_cap.max(t_max * bucket);
            let mut group = 0usize;
            for member in MatrixKind::ALL {
                if member.mask_source() == kind {
                    group += kept_io * self.store.layout.row_bytes(MatrixId::new(0, member));
                    w_cap = w_cap.max(bucket * spec.shape_of(member).cols);
                }
            }
            group_bytes_max = group_bytes_max.max(group);
            layer_bytes += group;
        }
        scratch.reserve(
            n_max,
            t_max,
            max_chunks,
            xs_cap,
            w_cap,
            group_bytes_max,
            layer_bytes,
        );
        // Pool fan-out scratch: a logical command gains at most one
        // extra piece per stripe block it crosses, so per-member command
        // capacity is bounded by the plan's worst command count plus the
        // total block count; staging is bounded by a whole layer landing
        // on one member.
        let pool_cmds = 7 * max_chunks + self.pool.stripe().num_blocks() + 1;
        scratch.pool.reserve(self.pool.len(), pool_cmds, layer_bytes);
        for slot in &mut state.prefetch {
            slot.reserve(layer_bytes, 7 * max_chunks, 7 * max_chunks);
        }
        for masks in state.prev_masks.iter_mut().chain(state.next_masks.iter_mut()) {
            for group in masks.iter_mut() {
                group.reserve(max_chunks);
            }
        }
    }

    /// Pre-rendered artifact name lookup (no per-call formatting).
    fn artifact_name(&self, base: &'static str, t: usize, bucket: usize) -> Result<&str> {
        self.artifact_names
            .get(&(base, t == 1, bucket))
            .map(|s| s.as_str())
            .with_context(|| format!("no artifact name for {base} t={t} r={bucket}"))
    }
}

/// Build the engine's storage pool: simulated members by default, or —
/// when `backing` names a directory — one wall-clock
/// [`crate::storage::RealFileDevice`] member per shard of the flash image
/// (the file-backed pool the async I/O overlap bench serves from). Files
/// are rewritten on every call, so re-calibration refreshes them too.
fn build_pool(
    profiles: &[DeviceProfile],
    stripe: StripeLayout,
    image: &[u8],
    seed: u64,
    backing: Option<&Path>,
) -> Result<DevicePool> {
    match backing {
        None => DevicePool::simulated(profiles, stripe, image, seed),
        Some(dir) => {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating backing dir {dir:?}"))?;
            let shards = stripe.shard_image(image);
            let mut paths = Vec::with_capacity(shards.len());
            for (m, data) in shards.iter().enumerate() {
                let path = dir.join(format!("member{m}.img"));
                std::fs::write(&path, data)
                    .with_context(|| format!("writing member image {path:?}"))?;
                paths.push(path);
            }
            DevicePool::from_files(&paths, stripe, 2, false)
        }
    }
}

/// Scale-free RMSNorm over each of `t` rows of width `d` (host-side; the
/// coordinator needs the values for scoring anyway).
pub fn rmsnorm(x: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut out = Vec::new();
    rmsnorm_into(x, t, d, &mut out);
    out
}

/// Allocation-free [`rmsnorm`]: clears and refills `out`.
pub fn rmsnorm_into(x: &[f32], t: usize, d: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(t * d, 0.0);
    for ti in 0..t {
        let row = &x[ti * d..(ti + 1) * d];
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (o, &v) in out[ti * d..(ti + 1) * d].iter_mut().zip(row) {
            *o = (v as f64 * inv) as f32;
        }
    }
}

/// Mean |activation| per column over `t` tokens (§B.2's multi-token
/// importance).
pub fn col_importance(x: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut imp = Vec::new();
    col_importance_into(x, t, d, &mut imp);
    imp
}

/// Allocation-free [`col_importance`]: clears and refills `out`.
pub fn col_importance_into(x: &[f32], t: usize, d: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(d, 0.0);
    for ti in 0..t {
        for j in 0..d {
            out[j] += x[ti * d + j].abs();
        }
    }
    let inv = 1.0 / t as f32;
    out.iter_mut().for_each(|v| *v *= inv);
}

fn full_mask(n: usize) -> SelectionMask {
    SelectionMask::full(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;
    use crate::sparsify::ChunkSelectConfig;
    use crate::workload::FrameTrace;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn build(policy: Policy, sparsity: f64) -> Engine {
        Engine::builder("tiny")
            .policy(policy)
            .sparsity(sparsity)
            .artifacts(&artifact_dir())
            .build()
            .unwrap()
    }

    fn frame(spec: &ModelSpec, idx: usize) -> Vec<f32> {
        FrameTrace::new(spec.d, spec.tokens_per_frame, 8, 7).frame(idx)
    }

    #[test]
    fn rmsnorm_unit_rms() {
        let x: Vec<f32> = (0..128).map(|i| (i as f32 - 64.0) * 0.3).collect();
        let out = rmsnorm(&x, 2, 64);
        for ti in 0..2 {
            let ms: f64 = out[ti * 64..(ti + 1) * 64]
                .iter()
                .map(|&v| (v as f64).powi(2))
                .sum::<f64>()
                / 64.0;
            assert!((ms - 1.0).abs() < 1e-3, "rms {ms}");
        }
    }

    #[test]
    fn col_importance_means_abs() {
        let x = vec![1.0f32, -2.0, 3.0, -4.0]; // t=2, d=2
        let imp = col_importance(&x, 2, 2);
        assert_eq!(imp, vec![2.0, 3.0]);
    }

    #[test]
    fn dense_engine_runs_and_is_deterministic() {
        let e1 = build(Policy::Dense, 0.0);
        let e2 = build(Policy::Dense, 0.0);
        let spec = e1.spec();
        let f = frame(&spec, 0);
        let s1 = e1.new_session();
        let s2 = e2.new_session();
        let (y1, st1) = s1.append_frame(&f).unwrap();
        let (y2, _) = s2.append_frame(&f).unwrap();
        assert_eq!(y1, y2);
        assert!(st1.io > Duration::ZERO);
        assert!(st1.compute > Duration::ZERO);
        assert_eq!(st1.bytes_loaded, spec.total_bytes());
        assert!((st1.retained_fraction() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sparsified_output_close_to_dense() {
        let f;
        let dense_out;
        {
            let dense = build(Policy::Dense, 0.0);
            f = frame(&dense.spec(), 1);
            dense_out = dense.new_session().append_frame(&f).unwrap().0;
        }
        let sparse = build(Policy::TopK, 0.25);
        let (sparse_out, stats) = sparse.new_session().append_frame(&f).unwrap();
        assert!(stats.bytes_loaded < sparse.spec().total_bytes());
        assert!(stats.retained_fraction() < 1.0);
        assert!(stats.retained_fraction() > 0.6);
        // Output error bounded relative to signal.
        let err: f64 = dense_out
            .iter()
            .zip(&sparse_out)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let norm: f64 = dense_out.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
        assert!(err / norm < 0.5, "rel err {}", err / norm);
    }

    #[test]
    fn chunking_loads_fewer_chunks_than_topk() {
        let mk = |policy| {
            Engine::builder("tiny")
                .policy(policy)
                .sparsity(0.4)
                .seed(9)
                .artifacts(&artifact_dir())
                .build()
                .unwrap()
        };
        let topk = mk(Policy::TopK);
        let chunk = mk(Policy::Chunking {
            config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
        });
        let f = frame(&topk.spec(), 2);
        let (_, st) = topk.new_session().append_frame(&f).unwrap();
        let (_, sc) = chunk.new_session().append_frame(&f).unwrap();
        assert!(
            sc.io <= st.io,
            "chunking io {:?} should not exceed topk {:?}",
            sc.io,
            st.io
        );
    }

    #[test]
    fn decode_after_append() {
        let e = build(Policy::TopK, 0.3);
        let s = e.new_session();
        let f = frame(&e.spec(), 0);
        s.append_frame(&f).unwrap();
        let token = vec![0.1f32; e.spec().d];
        let (y, stats) = s.decode_step(&token).unwrap();
        assert_eq!(y.len(), e.spec().d);
        assert!(stats.io > Duration::ZERO);
    }

    #[test]
    fn decode_without_append_rejected() {
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        let token = vec![0.1f32; e.spec().d];
        assert!(s.decode_step(&token).is_err());
    }

    #[test]
    fn sessions_are_isolated() {
        let e = build(Policy::Dense, 0.0);
        let s0 = e.new_session();
        let s1 = e.new_session();
        let f0 = frame(&e.spec(), 0);
        let f1 = frame(&e.spec(), 5);
        // Session 1 state must not affect session 0's output.
        let y_a = s0.append_frame(&f0).unwrap().0;
        s0.reset();
        s1.append_frame(&f1).unwrap();
        let y_b = s0.append_frame(&f0).unwrap().0;
        assert_eq!(y_a, y_b);
        assert!(s1.kv_tokens() > 0);
    }

    #[test]
    fn prefetch_serves_repeat_traffic_cheaper() {
        // Dense selections are perfectly predictable, so from the second
        // call on every non-first layer is fully covered by the prefetch
        // buffer and accounted I/O cannot exceed the cold call's (the
        // prefetched whole-layer read merges into fewer, larger commands
        // and earns the compute-overlap credit on top).
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        let f = frame(&e.spec(), 3);
        let (_, cold) = s.append_frame(&f).unwrap();
        assert_eq!(cold.prefetch_hits, 0, "first call has nothing prefetched");
        let (_, warm) = s.append_frame(&f).unwrap();
        assert!(warm.prefetch_hits > 0, "repeat call should hit the buffer");
        assert!(
            warm.io <= cold.io,
            "prefetched io {:?} vs cold {:?}",
            warm.io,
            cold.io
        );
        assert!(warm.prefetched_bytes > 0);
    }

    #[test]
    fn prefetch_off_matches_outputs() {
        let on = build(Policy::TopK, 0.4);
        let off = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .prefetch(false)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        let f0 = frame(&on.spec(), 0);
        let f1 = frame(&on.spec(), 1);
        let son = on.new_session();
        let soff = off.new_session();
        // Prefetch must be a pure timing optimization: outputs identical.
        assert_eq!(
            son.append_frame(&f0).unwrap().0,
            soff.append_frame(&f0).unwrap().0
        );
        let (y_on, st_on) = son.append_frame(&f1).unwrap();
        let (y_off, st_off) = soff.append_frame(&f1).unwrap();
        assert_eq!(y_on, y_off);
        assert_eq!(st_off.prefetch_hits, 0);
        assert!(st_on.prefetch_hits > 0);
    }

    #[test]
    fn async_io_is_a_pure_timing_change() {
        let sync = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .async_io(false)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        let pipelined = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .async_io(true)
            .io_queue_depth(2)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        assert!(pipelined.async_io());
        assert_eq!(pipelined.io_queue_depth(), 2);
        let f0 = frame(&sync.spec(), 0);
        let f1 = frame(&sync.spec(), 1);
        let ss = sync.new_session();
        let sa = pipelined.new_session();
        let (y0s, st0s) = ss.append_frame(&f0).unwrap();
        let (y0a, st0a) = sa.append_frame(&f0).unwrap();
        assert_eq!(y0s, y0a, "cold outputs diverged");
        assert_eq!(st0s.bytes_loaded, st0a.bytes_loaded);
        let (y1s, _) = ss.append_frame(&f1).unwrap();
        let (y1a, st1a) = sa.append_frame(&f1).unwrap();
        assert_eq!(y1s, y1a, "warm outputs diverged");
        // The warm call has in-flight prefetches and earns overlap.
        assert!(st1a.max_inflight >= 1);
        assert!(st1a.overlapped_io > Duration::ZERO);
        let r = st1a.overlap_ratio();
        assert!((0.0..=1.0).contains(&r), "overlap ratio {r}");
        let m = pipelined.metrics();
        assert!(m.total("io.overlapped") > Duration::ZERO);
        assert!(m.bytes("io.queue_depth") >= 1);
    }

    #[test]
    fn reorder_preserves_dense_output() {
        let plain = build(Policy::Dense, 0.0);
        let reordered = build(Policy::Dense, 0.0);
        let calib: Vec<Vec<f32>> = (0..3).map(|i| frame(&plain.spec(), i)).collect();
        reordered.calibrate_and_reorder(&calib).unwrap();
        let f = frame(&plain.spec(), 6);
        let (a, _) = plain.new_session().append_frame(&f).unwrap();
        let (b, _) = reordered.new_session().append_frame(&f).unwrap();
        // Dense compute is permutation-invariant: outputs must match to
        // float tolerance (summation order changes).
        let max_err = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "reorder changed dense output by {max_err}");
    }

    #[test]
    fn stale_session_resets_after_recalibration() {
        let e = build(Policy::Dense, 0.0);
        let s = e.new_session();
        let f = frame(&e.spec(), 0);
        s.append_frame(&f).unwrap();
        assert!(s.kv_tokens() > 0);
        let calib: Vec<Vec<f32>> = (0..2).map(|i| frame(&e.spec(), i)).collect();
        e.calibrate_and_reorder(&calib).unwrap();
        // The stale session must refuse decode (its KV died with the old
        // flash image) and transparently reset on the next append.
        assert!(s.decode_step(&vec![0.1; e.spec().d]).is_err());
        s.append_frame(&f).unwrap();
        assert!(s.kv_tokens() > 0);
    }

    #[test]
    fn reorder_improves_topk_contiguity_bytes() {
        // With reordering, top-k selections form fewer/larger chunks, so
        // simulated io time should not get worse.
        let plain = build(Policy::TopK, 0.4);
        let reordered = build(Policy::TopK, 0.4);
        let calib: Vec<Vec<f32>> = (0..4).map(|i| frame(&plain.spec(), i)).collect();
        reordered.calibrate_and_reorder(&calib).unwrap();
        let f = frame(&plain.spec(), 7);
        let (_, sp) = plain.new_session().append_frame(&f).unwrap();
        let (_, sr) = reordered.new_session().append_frame(&f).unwrap();
        assert!(
            sr.io.as_secs_f64() <= sp.io.as_secs_f64() * 1.05,
            "reordered io {:?} vs plain {:?}",
            sr.io,
            sp.io
        );
    }

    #[test]
    fn engine_handles_are_cloneable_and_sync() {
        fn assert_sync_send<T: Sync + Send>() {}
        assert_sync_send::<Engine>();
        assert_sync_send::<Session>();
        let e = build(Policy::TopK, 0.3);
        let e2 = e.clone();
        let f = frame(&e.spec(), 0);
        // Sessions opened from different handles share the same core.
        let a = e.new_session().append_frame(&f).unwrap().0;
        let b = e2.new_session().append_frame(&f).unwrap().0;
        assert_eq!(a, b);
    }

    #[test]
    fn pooled_engine_bit_identical_and_reports_per_device_io() {
        let single = build(Policy::TopK, 0.4);
        let pooled = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.4)
            .devices(3)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        assert_eq!(pooled.devices(), 3);
        let f = frame(&single.spec(), 2);
        let (a, sa) = single.new_session().append_frame(&f).unwrap();
        let (b, sb) = pooled.new_session().append_frame(&f).unwrap();
        // Sharding is a pure I/O-topology change: outputs and selections
        // are bit-identical to the single device.
        assert_eq!(a, b);
        assert_eq!(sa.bytes_loaded, sb.bytes_loaded);
        // Per-member accounting covers every transferred byte.
        let m = pooled.metrics();
        let dev_bytes: u64 = (0..3).map(|i| m.bytes(&format!("io.dev{i}"))).sum();
        assert_eq!(dev_bytes, sb.bytes_loaded);
        let busy = (0..3).filter(|&i| m.bytes(&format!("io.dev{i}")) > 0).count();
        assert!(busy >= 2, "striping should spread I/O over members, got {busy}");
    }

    #[test]
    fn heterogeneous_pool_serves() {
        let e = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .device_profiles(vec![DeviceProfile::nano(), DeviceProfile::agx()])
            .stripe_policy(StripePolicy::HotAware)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        assert_eq!(e.devices(), 2);
        let f = frame(&e.spec(), 1);
        let (y, st) = e.new_session().append_frame(&f).unwrap();
        assert!(y.iter().all(|v| v.is_finite()));
        assert!(st.io > Duration::ZERO);
    }

    #[test]
    fn pooled_reorder_matches_single_device() {
        let mk = |devices: usize| {
            let e = Engine::builder("tiny")
                .policy(Policy::TopK)
                .sparsity(0.4)
                .devices(devices)
                .artifacts(&artifact_dir())
                .build()
                .unwrap();
            let calib: Vec<Vec<f32>> = (0..3).map(|i| frame(&e.spec(), i)).collect();
            e.calibrate_and_reorder(&calib).unwrap();
            e.new_session().append_frame(&frame(&e.spec(), 5)).unwrap().0
        };
        assert_eq!(mk(1), mk(4));
    }

    #[test]
    fn into_variants_match_allocating_api() {
        let e = build(Policy::TopK, 0.4);
        let f = frame(&e.spec(), 2);
        let s1 = e.new_session();
        let s2 = e.new_session();
        let (y, _) = s1.append_frame(&f).unwrap();
        let mut y2 = Vec::new();
        s2.append_frame_into(&f, &mut y2).unwrap();
        assert_eq!(y, y2);
        let token = vec![0.07f32; e.spec().d];
        let (dy, _) = s1.decode_step(&token).unwrap();
        let mut dy2 = Vec::new();
        s2.decode_step_into(&token, &mut dy2).unwrap();
        assert_eq!(dy, dy2);
    }
}
