//! The L3 serving coordinator.
//!
//! [`Engine`] drives the per-matrix sparsification pipeline of §3 against
//! the AOT-compiled XLA artifacts: score activations → (permute) → select
//! chunks → read rows from flash → gather/pad to a budget bucket →
//! execute. [`Scheduler`] runs multi-stream frame-append/decode traffic
//! over one engine with priority batching. [`KvCache`] manages per-stream
//! attention state. [`HotNeuronCache`] implements the §5 memory-budget
//! extension (cached rows get zero importance and skip flash).

mod engine;
mod kv;
mod metrics;
mod neuron_cache;
mod scheduler;

pub use engine::{Engine, EngineConfig, StageStats};
pub use kv::KvCache;
pub use metrics::{Metrics, StageTimer};
pub use neuron_cache::HotNeuronCache;
pub use scheduler::{Completion, Request, RequestKind, Scheduler, SchedulerConfig};

use crate::sparsify::{Bundling, ChunkSelect, ChunkSelectConfig, Selector, Threshold, TopK};

/// Which selection policy the engine runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// No sparsification: every row is loaded from flash (offloaded dense).
    Dense,
    /// Magnitude top-k baseline.
    TopK,
    /// CATS-style calibrated threshold.
    Threshold { threshold: f32 },
    /// The paper's utility-guided chunk selection.
    Chunking { config: ChunkSelectConfig },
    /// LLM-in-a-Flash bundling baseline.
    Bundling { bundle_rows: usize },
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Dense => "dense",
            Policy::TopK => "topk",
            Policy::Threshold { .. } => "threshold",
            Policy::Chunking { .. } => "chunking",
            Policy::Bundling { .. } => "bundling",
        }
    }

    /// Instantiate the selector (None for Dense).
    pub fn selector(&self) -> Option<Box<dyn Selector>> {
        match self {
            Policy::Dense => None,
            Policy::TopK => Some(Box::new(TopK)),
            Policy::Threshold { threshold } => Some(Box::new(Threshold::new(*threshold))),
            Policy::Chunking { config } => Some(Box::new(ChunkSelect::new(*config))),
            Policy::Bundling { bundle_rows } => Some(Box::new(Bundling::new(*bundle_rows))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_selectors() {
        assert!(Policy::Dense.selector().is_none());
        assert_eq!(Policy::TopK.selector().unwrap().name(), "topk");
        let c = Policy::Chunking {
            config: ChunkSelectConfig::new(8.0, 8.0, 236.0),
        };
        assert_eq!(c.selector().unwrap().name(), "chunk_select");
        assert_eq!(
            Policy::Bundling { bundle_rows: 2 }.selector().unwrap().name(),
            "bundling"
        );
    }
}
