//! The L3 serving coordinator.
//!
//! [`Engine`] (built via [`EngineBuilder`]) drives the per-matrix
//! sparsification pipeline of §3: score activations → (permute) → select
//! chunks → plan the group's flash reads → submit one cross-matrix command
//! batch → gather/pad to a budget bucket → execute. The engine core is
//! `Sync` (read-mostly state behind `Arc<RwLock>`); serving state lives
//! in per-stream [`Session`] handles (KV caches, next-layer prefetch, and
//! a scratch arena that makes the steady-state path allocation-free).
//! The per-layer stage sequence itself lives in `pipeline/` (normalize →
//! score/select → plan → submit/await → execute → scatter), whose batch
//! driver also serves **cross-stream decode batches**: concurrent decode
//! requests ([`DecodeRequest`]) run stage-synchronously with fused I/O
//! plans (shared chunks read once) and multi-stream kernels, bit-identical
//! to solo decoding. [`Scheduler`] runs multi-stream frame-append/decode
//! traffic over one engine with priority batching across a configurable
//! worker pool, forming fused decode batches inside a bounded window.
//! [`HotNeuronCache`] implements the §5 memory-budget extension (cached
//! rows get zero importance and skip flash).

mod arena;
mod engine;
mod kv;
mod metrics;
mod neuron_cache;
mod pipeline;
mod scheduler;

pub use engine::{Engine, EngineBuilder, Session};
pub use kv::{KvCache, KvMark};
pub use metrics::{Metrics, StageTimer};
pub use neuron_cache::HotNeuronCache;
pub use pipeline::batch::{DecodeRequest, MAX_DECODE_BATCH};
pub use pipeline::stages::{col_importance, col_importance_into, rmsnorm, rmsnorm_into};
pub use pipeline::StageStats;
pub use scheduler::{
    AdmissionSnapshot, Class, ClassSnapshot, Completion, Request, RequestOpts, Scheduler,
    SchedulerConfig, SubmitError,
};

use crate::sparsify::{Bundling, ChunkSelect, ChunkSelectConfig, Selector, Threshold, TopK};

/// Which selection policy the engine runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// No sparsification: every row is loaded from flash (offloaded dense).
    Dense,
    /// Magnitude top-k baseline.
    TopK,
    /// CATS-style calibrated threshold.
    Threshold { threshold: f32 },
    /// The paper's utility-guided chunk selection.
    Chunking { config: ChunkSelectConfig },
    /// LLM-in-a-Flash bundling baseline.
    Bundling { bundle_rows: usize },
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Dense => "dense",
            Policy::TopK => "topk",
            Policy::Threshold { .. } => "threshold",
            Policy::Chunking { .. } => "chunking",
            Policy::Bundling { .. } => "bundling",
        }
    }

    /// Instantiate the selector (None for Dense).
    pub fn selector(&self) -> Option<Box<dyn Selector>> {
        match self {
            Policy::Dense => None,
            Policy::TopK => Some(Box::new(TopK)),
            Policy::Threshold { threshold } => Some(Box::new(Threshold::new(*threshold))),
            Policy::Chunking { config } => Some(Box::new(ChunkSelect::new(*config))),
            Policy::Bundling { bundle_rows } => Some(Box::new(Bundling::new(*bundle_rows))),
        }
    }

    /// Re-tune device-dependent knobs for a device's saturation point (KB):
    /// chunking's largest candidate window is the saturation chunk size.
    pub fn tuned_for_saturation(self, sat_kb: f64) -> Policy {
        match self {
            Policy::Chunking { mut config } => {
                config.max_kb = sat_kb;
                Policy::Chunking { config }
            }
            other => other,
        }
    }
}

/// Error from parsing a [`Policy`] string.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParsePolicyError {
    input: String,
    reason: String,
}

impl std::fmt::Display for ParsePolicyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "invalid policy {:?}: {} (expected dense | topk | threshold[:t] | \
             chunking[:min_kb,jump_kb,max_kb] | bundling[:rows])",
            self.input, self.reason
        )
    }
}

impl std::error::Error for ParsePolicyError {}

/// Parse a policy from CLI syntax: a bare name (`dense`, `topk`,
/// `threshold`, `chunking`, `bundling`) or a name with `:`-separated
/// parameters (`threshold:0.1`, `chunking:2,2,348`, `bundling:4`).
impl std::str::FromStr for Policy {
    type Err = ParsePolicyError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let err = |reason: &str| ParsePolicyError {
            input: s.to_string(),
            reason: reason.to_string(),
        };
        let (name, args) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        match (name, args) {
            ("dense", None) => Ok(Policy::Dense),
            ("topk", None) => Ok(Policy::TopK),
            ("dense" | "topk", Some(_)) => Err(err("policy takes no parameters")),
            ("threshold", None) => Ok(Policy::Threshold { threshold: 0.05 }),
            ("threshold", Some(a)) => a
                .parse::<f32>()
                .ok()
                .filter(|t| t.is_finite() && *t >= 0.0)
                .map(|threshold| Policy::Threshold { threshold })
                .ok_or_else(|| err("threshold must be a finite non-negative float")),
            ("chunking", None) => Ok(Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, 348.0),
            }),
            ("chunking", Some(a)) => {
                let parts: Vec<&str> = a.split(',').collect();
                if parts.len() != 3 {
                    return Err(err("chunking takes min_kb,jump_kb,max_kb"));
                }
                let nums: Result<Vec<f64>, _> =
                    parts.iter().map(|p| p.parse::<f64>()).collect();
                match nums {
                    Ok(v) if v.iter().all(|&x| x > 0.0) => Ok(Policy::Chunking {
                        config: ChunkSelectConfig::new(v[0], v[1], v[2]),
                    }),
                    _ => Err(err("chunking parameters must be positive floats")),
                }
            }
            ("bundling", None) => Ok(Policy::Bundling { bundle_rows: 2 }),
            ("bundling", Some(a)) => a
                .parse::<usize>()
                .ok()
                .filter(|&n| n > 0)
                .map(|bundle_rows| Policy::Bundling { bundle_rows })
                .ok_or_else(|| err("bundling rows must be a positive integer")),
            _ => Err(err("unknown policy name")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_selectors() {
        assert!(Policy::Dense.selector().is_none());
        assert_eq!(Policy::TopK.selector().unwrap().name(), "topk");
        let c = Policy::Chunking {
            config: ChunkSelectConfig::new(8.0, 8.0, 236.0),
        };
        assert_eq!(c.selector().unwrap().name(), "chunk_select");
        assert_eq!(
            Policy::Bundling { bundle_rows: 2 }.selector().unwrap().name(),
            "bundling"
        );
    }

    #[test]
    fn parses_bare_names() {
        assert_eq!("dense".parse::<Policy>().unwrap(), Policy::Dense);
        assert_eq!("topk".parse::<Policy>().unwrap(), Policy::TopK);
        assert_eq!(
            "bundling".parse::<Policy>().unwrap(),
            Policy::Bundling { bundle_rows: 2 }
        );
        assert!(matches!(
            "threshold".parse::<Policy>().unwrap(),
            Policy::Threshold { .. }
        ));
        assert!(matches!(
            "chunking".parse::<Policy>().unwrap(),
            Policy::Chunking { .. }
        ));
    }

    #[test]
    fn parses_parameters() {
        assert_eq!(
            "threshold:0.125".parse::<Policy>().unwrap(),
            Policy::Threshold { threshold: 0.125 }
        );
        assert_eq!(
            "bundling:4".parse::<Policy>().unwrap(),
            Policy::Bundling { bundle_rows: 4 }
        );
        assert_eq!(
            "chunking:4,8,236".parse::<Policy>().unwrap(),
            Policy::Chunking {
                config: ChunkSelectConfig::new(4.0, 8.0, 236.0)
            }
        );
    }

    #[test]
    fn unknown_policy_is_an_error() {
        for bad in [
            "nope",
            "",
            "dense:1",
            "topk:3",
            "threshold:abc",
            "threshold:nan",
            "threshold:-0.5",
            "threshold:inf",
            "chunking:1,2",
            "chunking:0,2,3",
            "bundling:0",
            "bundling:x",
        ] {
            let e = bad.parse::<Policy>();
            assert!(e.is_err(), "{bad:?} should not parse");
            let msg = e.unwrap_err().to_string();
            assert!(msg.contains("invalid policy"), "{msg}");
        }
    }

    #[test]
    fn round_trips_through_name() {
        for p in ["dense", "topk", "threshold", "chunking", "bundling"] {
            assert_eq!(p.parse::<Policy>().unwrap().name(), p);
        }
    }

    #[test]
    fn tuning_rewrites_chunking_saturation() {
        let p = "chunking".parse::<Policy>().unwrap().tuned_for_saturation(236.0);
        match p {
            Policy::Chunking { config } => assert_eq!(config.max_kb, 236.0),
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(
            Policy::TopK.tuned_for_saturation(100.0),
            Policy::TopK
        );
    }
}
