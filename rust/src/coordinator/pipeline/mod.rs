//! The staged decode pipeline: the per-session serving path, modelled as
//! explicit per-layer stages, plus the cross-stream batch driver built on
//! top of them.
//!
//! Every serving call (frame append or decode step) runs each transformer
//! layer through the same stage sequence, one pass per selection group
//! (qkv+attention, o-proj, gate/up, down-proj):
//!
//! 1. **normalize/score** ([`EngineCore::score_group`]) — RMS-norm the
//!    stage input where the reference model does, reduce it to per-column
//!    importance;
//! 2. **select** ([`EngineCore::select_into`]) — run the sparsification
//!    policy under the (pool-effective) latency model;
//! 3. **plan** ([`EngineCore::prepare_group_load`]) — subtract what the
//!    layer prefetch buffer already holds, plan the residual demand as
//!    one cross-matrix command batch, gather the activation columns;
//! 4. **submit/await** ([`EngineCore::submit_pooled`]) — one pooled flash
//!    submission per group (the async pipeline moves the *prefetch*
//!    submissions ahead of compute and awaits them here);
//! 5. **execute** ([`EngineCore::exec_group_solo`]) — run the compiled
//!    stage artifact over the gathered weights;
//! 6. **scatter** — stage outputs land back in the session's activation
//!    buffers, KV caches append, and the demand is recorded for the next
//!    call's prefetch prediction.
//!
//! [`forward`](EngineCore::forward) drives a single stream through those
//! stages; [`batch`] drives a whole [`DecodeBatch`-style
//! group](crate::coordinator::DecodeRequest) of streams through them
//! stage-synchronously, fusing the per-stream plans at step 4 so chunks
//! demanded by several streams are read from flash **once**
//! ([`crate::plan::IoPlanner::fuse_into`]) and executing each shared
//! weight tile across all member streams' activations at step 5
//! ([`crate::runtime::XlaRuntime::execute_batched_into`]).
//!
//! Hard invariant, shared by both drivers and pinned by the determinism
//! tests: a stream's outputs and selected-chunk sets are **bit-identical**
//! whether it decodes solo or inside any batch composition, at any queue
//! depth and pool size.

pub(crate) mod batch;
pub(crate) mod prefill;
pub(crate) mod stages;

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::arena::ScratchArena;
use crate::coordinator::engine::EngineCore;
use crate::coordinator::KvCache;
use crate::latency::Chunk;
use crate::model::{MatrixId, MatrixKind, ModelSpec};
use crate::plan::{PlanReceipt, PlanScratch, PlannedRead, ReadPlan};
use crate::storage::{IoTicket, PoolScratch};

/// Per-call stage accounting (one frame append or decode step).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageStats {
    /// Flash service time (virtual for simulated devices), after prefetch
    /// overlap credit.
    pub io: Duration,
    /// Stage-artifact execution wall time.
    pub compute: Duration,
    /// Selection-algorithm wall time.
    pub select: Duration,
    /// Host gather/pad/norm wall time.
    pub host: Duration,
    pub bytes_loaded: u64,
    /// Bytes loaded speculatively by the next-layer prefetcher (subset of
    /// `bytes_loaded`).
    pub prefetched_bytes: u64,
    /// Weight rows served from the prefetch buffer instead of a fresh
    /// flash read.
    pub prefetch_hits: u64,
    /// Flash bytes the shared chunk cache served from RAM this call —
    /// demand that never reached the device pool. Disjoint from
    /// `bytes_loaded` (which counts only bytes actually read), so
    /// metrics can tell "less I/O" apart from "less work".
    pub cache_hit_bytes: u64,
    /// Flash service time hidden behind compute by the prefetch pipeline
    /// (the overlap credit already subtracted from `io`).
    pub overlapped_io: Duration,
    /// Highest number of whole-layer prefetches in flight at once (async
    /// I/O pipeline only; 0 otherwise).
    pub max_inflight: u64,
    /// Retained / total importance this call (accuracy proxy).
    pub importance_kept: f64,
    pub importance_total: f64,
}

impl StageStats {
    pub fn end_to_end(&self) -> Duration {
        self.io + self.compute + self.select + self.host
    }

    /// Fraction of total flash service time that was hidden behind
    /// compute (`overlapped / (charged + overlapped)`), in [0, 1].
    pub fn overlap_ratio(&self) -> f64 {
        let total = self.io + self.overlapped_io;
        if total.is_zero() {
            0.0
        } else {
            self.overlapped_io.as_secs_f64() / total.as_secs_f64()
        }
    }

    pub fn retained_fraction(&self) -> f64 {
        if self.importance_total <= 0.0 {
            1.0
        } else {
            self.importance_kept / self.importance_total
        }
    }

    /// Merge another call's stats (used by aggregating drivers).
    pub fn absorb(&mut self, other: &StageStats) {
        self.io += other.io;
        self.compute += other.compute;
        self.select += other.select;
        self.host += other.host;
        self.bytes_loaded += other.bytes_loaded;
        self.prefetched_bytes += other.prefetched_bytes;
        self.prefetch_hits += other.prefetch_hits;
        self.cache_hit_bytes += other.cache_hit_bytes;
        self.overlapped_io += other.overlapped_io;
        self.max_inflight = self.max_inflight.max(other.max_inflight);
        self.importance_kept += other.importance_kept;
        self.importance_total += other.importance_total;
    }
}

/// Group index within [`MatrixKind::SCORED`] (Q, O, Gate, Down).
pub(crate) fn group_index(kind: MatrixKind) -> usize {
    MatrixKind::SCORED
        .iter()
        .position(|&k| k == kind)
        .expect("scored kind")
}

/// Per-group flash-chunk demand recorded for next-call prefetch. An empty
/// list means "no demand recorded".
pub(crate) type GroupChunks = [Vec<Chunk>; 4];

/// Per-call analytic clock for virtual-pool async accounting. Virtual
/// waits charged to `io` do not advance the real wall clock (nothing
/// actually sleeps), so the stall already charged this call is carried
/// explicitly: the analytic "now" is wall-now plus that stall, the
/// device frees up at the last submission's completion, and each
/// charge is the time remaining from the analytic now — queued reads
/// serialize without double-counting the backlog across stages.
pub(crate) struct VirtualClock {
    /// Analytic completion of the latest virtual submission.
    free_at: Instant,
    /// Virtual stall time already charged to `io` this call.
    stall: Duration,
}

impl VirtualClock {
    fn start() -> Self {
        Self {
            free_at: Instant::now(),
            stall: Duration::ZERO,
        }
    }

    /// The analytic current time: wall clock advanced by charged stalls.
    fn now(&self) -> Instant {
        Instant::now() + self.stall
    }
}

/// Submission state of one layer's in-flight prefetch (async pipeline).
#[derive(Default)]
pub(crate) enum PendingPrefetch {
    /// Nothing submitted for this layer.
    #[default]
    Idle,
    /// Submitted inline against an all-virtual-clock pool: the receipt is
    /// already filled; `completion` places the read's analytic finish on
    /// the wall timeline under a *device-serial* queueing model
    /// (`completion = max(submit, device-free) + service` — concurrent
    /// in-flight reads queue behind each other instead of each crediting
    /// the same compute window), and the overlap credit is settled when
    /// the layer consumes it.
    Virtual { completion: Instant, service: Duration },
    /// Submitted to the async I/O workers (wall-clock pool): the ticket
    /// completes once every member's sub-plan has been read.
    InFlight { ticket: IoTicket },
}

pub(crate) struct SessionState {
    /// KV caches, one per layer.
    pub(crate) kvs: Vec<KvCache>,
    /// Flash chunks each (layer, group) demanded on the previous call —
    /// the prefetch prediction source.
    pub(crate) prev_masks: Vec<GroupChunks>,
    /// This call's demand record; swapped into `prev_masks` at call end.
    pub(crate) next_masks: Vec<GroupChunks>,
    /// Pooled prefetched whole-layer reads, one slot per layer (an empty
    /// plan means "nothing prefetched").
    pub(crate) prefetch: Vec<PlannedRead>,
    /// Async-pipeline submission state, one slot per layer. Every
    /// non-`Idle` entry is consumed at its layer within the same call;
    /// entries only survive a call when it aborted mid-pipeline, and are
    /// drained before the next one begins.
    pub(crate) pending: Vec<PendingPrefetch>,
    pub(crate) epoch: u64,
}

impl SessionState {
    pub(crate) fn new(spec: &ModelSpec, epoch: u64) -> Self {
        Self {
            kvs: (0..spec.layers)
                .map(|_| KvCache::new(spec.cache_slots, spec.d))
                .collect(),
            prev_masks: (0..spec.layers).map(|_| GroupChunks::default()).collect(),
            next_masks: (0..spec.layers).map(|_| GroupChunks::default()).collect(),
            prefetch: (0..spec.layers).map(|_| PlannedRead::default()).collect(),
            pending: (0..spec.layers).map(|_| PendingPrefetch::default()).collect(),
            epoch,
        }
    }

    /// Settle any submission a previous (aborted) call left behind: await
    /// and discard in-flight tickets, clear the matching prefetch slots.
    /// No-op (and allocation-free) when every entry is `Idle`. Both
    /// serving drivers and [`SessionState::reset`] run this, so a reset
    /// mid-pipeline can never scatter stale bytes into the next request.
    pub(crate) fn drain_stale(&mut self) {
        for (slot, pending) in self.prefetch.iter_mut().zip(self.pending.iter_mut()) {
            match std::mem::take(pending) {
                PendingPrefetch::Idle => {}
                PendingPrefetch::Virtual { .. } => slot.clear(),
                PendingPrefetch::InFlight { ticket } => {
                    ticket.discard();
                    slot.clear();
                }
            }
        }
    }

    pub(crate) fn reset(&mut self, epoch: u64) {
        self.drain_stale();
        for kv in &mut self.kvs {
            kv.clear();
        }
        for masks in self.prev_masks.iter_mut().chain(self.next_masks.iter_mut()) {
            for group in masks.iter_mut() {
                group.clear();
            }
        }
        for slot in &mut self.prefetch {
            slot.clear();
        }
        self.epoch = epoch;
    }
}

/// Loop state of one in-progress forward call, split out so a driver can
/// pause between layer boundaries (the chunked prefill path) and resume
/// later. Every field is owned — no borrows of the core, session, or
/// scratch survive a pause — which is what lets the scheduler's worker
/// drop every lock at a yield point and serve decode batches in between.
///
/// Pausing changes **no** floating-point computation: the layer loop body
/// is byte-for-byte the one [`EngineCore::forward`] runs, so a chunked
/// pass is bit-identical to a monolithic one. Only the timing fields
/// (virtual clock, stage stats) observe the pause.
pub(crate) struct ForwardPass {
    /// Tokens in this call (frame length for prefill, 1 for decode).
    pub(crate) t: usize,
    /// Next layer to run; the pass is done when `layer == layers`.
    pub(crate) layer: usize,
    layers: usize,
    stats: StageStats,
    prefetch_service: Duration,
    /// Per-call analytic clock for the virtual-pool queueing model
    /// (virtual-clock pools only; wall-clock pools measure real time).
    vclock: VirtualClock,
    in_flight: u64,
    next_submit: usize,
    async_on: bool,
    depth: usize,
    /// Engine epoch captured at [`EngineCore::begin_pass`]; a resuming
    /// driver must abort the pass if the core re-calibrated in between.
    pub(crate) epoch: u64,
    /// Times the pass was resumed after a yield (0 for monolithic calls).
    pub(crate) resumes: u64,
}

impl ForwardPass {
    pub(crate) fn done(&self) -> bool {
        self.layer >= self.layers
    }
}

impl EngineCore {
    /// One serving call (frame append or decode step) of a single stream:
    /// the solo driver over the staged pipeline. `&self`: all mutable
    /// state lives in the session (`state` + `scratch`), so concurrent
    /// sessions proceed under the shared read lock.
    ///
    /// This is exactly `begin_pass` + every `run_layer` + `finish_pass`
    /// back to back; the chunked prefill driver ([`prefill`]) runs the
    /// same three primitives with pauses between layer boundaries.
    pub(crate) fn forward(
        &self,
        state: &mut SessionState,
        scratch: &mut ScratchArena,
        input: &[f32],
        t: usize,
        out: &mut Vec<f32>,
    ) -> Result<StageStats> {
        let mut pass = self.begin_pass(state, scratch, input, t);
        while !pass.done() {
            self.run_layer(state, scratch, &mut pass)?;
        }
        Ok(self.finish_pass(state, scratch, pass, out))
    }

    /// Start a forward pass: reset stale session state, seed the
    /// activation buffer, and capture the loop state the layer driver
    /// threads through [`EngineCore::run_layer`].
    pub(crate) fn begin_pass(
        &self,
        state: &mut SessionState,
        scratch: &mut ScratchArena,
        input: &[f32],
        t: usize,
    ) -> ForwardPass {
        if state.epoch != self.epoch {
            state.reset(self.epoch);
        }
        let sc = &mut *scratch;
        sc.pool.accum.reset(self.pool.len());
        sc.fwd.xa.clear();
        sc.fwd.xa.extend_from_slice(input);

        // Async pipeline state: keep up to `io_queue_depth` whole-layer
        // prefetches in flight, each submitted *before* the kernels of
        // the layers it overlaps with run, and awaited only at the moment
        // its layer consumes the weights.
        let async_on = self.async_io && self.prefetch;
        if async_on {
            state.drain_stale();
        }
        ForwardPass {
            t,
            layer: 0,
            layers: self.spec.layers,
            stats: StageStats::default(),
            prefetch_service: Duration::ZERO,
            vclock: VirtualClock::start(),
            in_flight: 0,
            next_submit: 1,
            async_on,
            depth: self.io_queue_depth.max(1),
            epoch: self.epoch,
            resumes: 0,
        }
    }

    /// Run the next layer of an in-progress pass (all four selection
    /// groups through the stage sequence), advancing `pass.layer`.
    pub(crate) fn run_layer(
        &self,
        state: &mut SessionState,
        scratch: &mut ScratchArena,
        pass: &mut ForwardPass,
    ) -> Result<()> {
        let sc = &mut *scratch;
        let layer = pass.layer;
        let layers = pass.layers;
        let t = pass.t;
        let layer_t0 = Instant::now();
        if pass.async_on {
            // Await this layer's prefetch (if one is in flight) right
            // before its weights are consumed; only service time the
            // intervening compute could not hide is charged.
            pass.in_flight -= self.consume_pending(
                state,
                sc,
                layer,
                &mut pass.stats,
                &mut pass.prefetch_service,
                &mut pass.vclock,
            )?;
            // Then top up the submission window before this layer's
            // kernels execute. Consuming first keeps the bound exact:
            // at most `depth` layers are ever in flight per session,
            // so a submission never blocks on a full member queue
            // ahead of this layer's compute (the queues carry slack
            // for several concurrent sessions; past that, a full
            // queue is deliberate backpressure).
            while pass.next_submit < layers && pass.next_submit <= layer + pass.depth {
                let l = pass.next_submit;
                pass.next_submit += 1;
                if self.submit_prefetch(state, sc, l, &mut pass.stats, &mut pass.vclock)? {
                    pass.in_flight += 1;
                    pass.stats.max_inflight = pass.stats.max_inflight.max(pass.in_flight);
                }
            }
        }
        // Whole-layer prefetch buffer for this layer, if the previous
        // call's masks were submitted while layer-1 executed. Swap the
        // pooled slot out (its buffers cycle back in on the next
        // prefetch write) and leave the slot empty.
        std::mem::swap(&mut sc.pre, &mut state.prefetch[layer]);
        state.prefetch[layer].clear();
        let pre = if sc.pre.is_empty() { None } else { Some(&sc.pre) };
        let stats = &mut pass.stats;

        for group in 0..4 {
            let kind = MatrixKind::SCORED[group];
            // normalize → score → select.
            self.score_group(group, t, &mut sc.fwd, stats);
            self.select_into(
                layer,
                kind,
                &sc.fwd.imp,
                stats,
                &mut sc.sel_scratch,
                &mut sc.imp_phys,
                &mut sc.sel,
            );
            // Plan the residual demand, gather activation columns.
            let acts: &[f32] = match group {
                0 | 2 => &sc.fwd.hn,
                1 => &sc.fwd.attn,
                _ => &sc.fwd.act,
            };
            let bucket = self.prepare_group_load(
                layer,
                kind,
                acts,
                t,
                &sc.sel,
                pre,
                &mut sc.gather,
                &mut sc.plan_scratch,
                stats,
            );
            // Record the demand for next-call prefetch prediction.
            let dst = &mut state.next_masks[layer][group];
            dst.clear();
            dst.extend_from_slice(&sc.gather.flash_chunks);
            // Submit the group's planned read through the pool.
            if sc.gather.fresh.plan.is_empty() {
                sc.gather.fresh.receipt.clear();
            } else {
                let PlannedRead { plan, receipt } = &mut sc.gather.fresh;
                self.submit_pooled(plan, &mut sc.pool, receipt)?;
                stats.bytes_loaded += plan.payload_bytes();
            }
            stats.io += sc.gather.fresh.receipt.service;
            // Assemble the weight tile and execute the stage.
            self.gather_group_weights(layer, kind, bucket, pre, &mut sc.gather, stats);
            self.exec_group_solo(
                group,
                t,
                bucket,
                &mut state.kvs[layer],
                &sc.gather,
                &mut sc.fwd,
                &mut sc.exec,
                &mut sc.outs,
                stats,
            )?;
        }

        // --- double-buffered prefetch of layer l+1 (sync mode) ---
        // Submit the next layer's predicted whole-layer read now; the
        // service time it cannot hide behind this layer's compute is
        // what the caller pays. (The async pipeline replaces this
        // with submit-ahead at layer start + await-at-consumption.)
        if !pass.async_on && self.prefetch && layer + 1 < layers {
            pass.prefetch_service += self.prefetch_layer(
                state,
                &mut sc.plan_scratch,
                &mut sc.pool,
                layer + 1,
                layer_t0.elapsed(),
                &mut pass.stats,
            )?;
        }
        pass.layer += 1;
        Ok(())
    }

    /// Finish a completed pass: swap the demand masks for next-call
    /// prefetch prediction, fold the call's metrics once, and copy the
    /// final activations out.
    pub(crate) fn finish_pass(
        &self,
        state: &mut SessionState,
        scratch: &mut ScratchArena,
        pass: ForwardPass,
        out: &mut Vec<f32>,
    ) -> StageStats {
        debug_assert!(pass.done());
        let sc = &mut *scratch;
        let stats = pass.stats;
        std::mem::swap(&mut state.prev_masks, &mut state.next_masks);
        // One metrics fold per call (not per stage): the shared mutex is
        // touched once, so concurrent sessions don't serialize on it.
        {
            let mut metrics = self.metrics.lock().unwrap();
            metrics.add("host", stats.host);
            metrics.add("select", stats.select);
            metrics.add("compute", stats.compute);
            metrics.add("io", stats.io);
            if pass.prefetch_service > Duration::ZERO {
                metrics.add("prefetch", pass.prefetch_service);
                // Service time the pipeline hid behind compute; the
                // overlap ratio is `io.overlapped / (io + io.overlapped)`.
                metrics.add("io.overlapped", stats.overlapped_io);
            }
            if pass.async_on {
                // Per-call max of in-flight whole-layer prefetches
                // (accumulated; divide by the "io" call count for the
                // average achieved queue depth).
                metrics.add_bytes("io.queue_depth", stats.max_inflight);
            }
            metrics.add_bytes("io", stats.bytes_loaded);
            // Same bytes, keyed by the storage dtype that encoded them —
            // `/metrics` exposes per-dtype flash traffic with no lookup.
            metrics.add_bytes(self.io_dtype_bytes, stats.bytes_loaded);
            if stats.cache_hit_bytes > 0 {
                metrics.add_bytes("io.cache_hit_bytes", stats.cache_hit_bytes);
            }
            if pass.resumes > 0 {
                // Yield points actually taken by a chunked prefill pass.
                metrics.add_bytes("prefill.yields", pass.resumes);
            }
            // Per-member I/O accounting (multi-member pools only): bytes
            // and summed service per device, from which utilization skew
            // is derived. Keys are pre-rendered, so this allocates
            // nothing at steady state.
            if self.pool.len() > 1 {
                for m in 0..self.pool.len() {
                    metrics.add(&self.dev_io_names[m], sc.pool.accum.service[m]);
                    metrics.add_bytes(&self.dev_io_names[m], sc.pool.accum.bytes[m]);
                }
            }
        }
        out.clear();
        out.extend_from_slice(&sc.fwd.xa);
        stats
    }

    /// Plan the predicted flash demand of `layer` (all four selection
    /// groups, every member matrix — one cross-matrix command batch) into
    /// the session's pooled prefetch slot. Returns whether the plan is
    /// non-empty. Allocation-free.
    pub(crate) fn plan_layer_prefetch(
        &self,
        state: &mut SessionState,
        plan_scratch: &mut PlanScratch,
        layer: usize,
    ) -> bool {
        let SessionState {
            prev_masks,
            prefetch,
            ..
        } = state;
        let Some(groups) = prev_masks.get(layer) else {
            return false;
        };
        // At most the seven matrices of one layer; stack-allocated.
        let empty: &[Chunk] = &[];
        let mut requests: [(MatrixId, &[Chunk]); 7] =
            [(MatrixId::new(layer, MatrixKind::Q), empty); 7];
        let mut n = 0usize;
        for (gi, scored) in MatrixKind::SCORED.into_iter().enumerate() {
            let chunks = &groups[gi];
            if chunks.is_empty() {
                continue;
            }
            for member in MatrixKind::ALL {
                if member.mask_source() == scored {
                    requests[n] = (MatrixId::new(layer, member), chunks.as_slice());
                    n += 1;
                }
            }
        }
        if n == 0 {
            return false;
        }
        let slot = &mut prefetch[layer];
        self.planner.plan_refs_into(
            &self.store.layout,
            &requests[..n],
            Some(&self.table),
            plan_scratch,
            &mut slot.plan,
        );
        !slot.plan.is_empty()
    }

    /// Synchronous-mode prefetch: plan + submit `layer`'s predicted
    /// demand into its slot. `overlap` is the wall-clock compute window
    /// already elapsed that the prefetch hides behind. Returns the raw
    /// (pre-overlap-credit) service time for the caller's metrics fold.
    pub(crate) fn prefetch_layer(
        &self,
        state: &mut SessionState,
        plan_scratch: &mut PlanScratch,
        pool_scratch: &mut PoolScratch,
        layer: usize,
        overlap: Duration,
        stats: &mut StageStats,
    ) -> Result<Duration> {
        if !self.plan_layer_prefetch(state, plan_scratch, layer) {
            return Ok(Duration::ZERO);
        }
        let PlannedRead { plan, receipt } = &mut state.prefetch[layer];
        if let Err(e) = self.submit_pooled(plan, pool_scratch, receipt) {
            // A failed submission must not leave a non-empty plan over an
            // unfilled receipt: the next call would swap the slot in as a
            // valid prefetch and serve garbage bytes.
            state.prefetch[layer].clear();
            return Err(e);
        }
        let PlannedRead { plan, receipt } = &mut state.prefetch[layer];
        let service = receipt.service;
        let charged = service.saturating_sub(overlap);
        stats.io += charged;
        stats.overlapped_io += service - charged;
        stats.bytes_loaded += plan.payload_bytes();
        stats.prefetched_bytes += plan.payload_bytes();
        Ok(service)
    }

    /// Async-pipeline submission of `layer`'s predicted prefetch demand.
    /// Returns whether anything was submitted (and is now in flight).
    ///
    /// Virtual-clock pools submit inline (an analytical clock cannot
    /// observe concurrency — the data and service time are exact either
    /// way) and place the read's analytic completion on the wall
    /// timeline under the device-serial queueing model of
    /// [`VirtualClock`]; the overlap credit is settled in
    /// [`EngineCore::consume_pending`]. Wall-clock pools hand the
    /// sharded plan to the per-member I/O workers and hold the
    /// completion ticket.
    fn submit_prefetch(
        &self,
        state: &mut SessionState,
        sc: &mut ScratchArena,
        layer: usize,
        stats: &mut StageStats,
        vclock: &mut VirtualClock,
    ) -> Result<bool> {
        if !self.plan_layer_prefetch(state, &mut sc.plan_scratch, layer) {
            return Ok(false);
        }
        let SessionState {
            prefetch, pending, ..
        } = state;
        let PlannedRead { plan, receipt } = &mut prefetch[layer];
        stats.bytes_loaded += plan.payload_bytes();
        stats.prefetched_bytes += plan.payload_bytes();
        match &self.async_pipe {
            None => {
                if let Err(e) = self.submit_pooled(plan, &mut sc.pool, receipt) {
                    // Never leave a non-empty plan over an unfilled
                    // receipt: the next call would swap the slot in as a
                    // valid prefetch and serve garbage bytes.
                    prefetch[layer].clear();
                    return Err(e);
                }
                let service = prefetch[layer].receipt.service;
                // Device-serial virtual queueing: this read starts when
                // the (pool-level) virtual device frees up, never before
                // the analytic now — concurrent in-flight prefetches
                // must not each credit the same compute window.
                let start = vclock.free_at.max(vclock.now());
                let completion = start + service;
                vclock.free_at = completion;
                pending[layer] = PendingPrefetch::Virtual {
                    completion,
                    service,
                };
            }
            Some(pipe) => {
                if self.pool.needs_routing() {
                    self.pool.route_plan(plan, &mut sc.pool.sharded);
                } else {
                    self.planner
                        .shard_into(plan, self.pool.stripe(), &mut sc.pool.sharded);
                }
                // Pre-size the logical receipt here; the workers fill
                // their own staging buffers and the ticket scatters into
                // these bytes at await time.
                let total = receipt.presize_for(plan.cmds());
                if sc.pool.sharded.total_bytes() != total {
                    let covered = sc.pool.sharded.total_bytes();
                    prefetch[layer].clear();
                    anyhow::bail!("sharded prefetch covers {covered} of {total} plan bytes");
                }
                // Routed plans over replicated stripes get hedged
                // completion (stragglers re-issued to another replica);
                // unrouted plans fall through to a plain ticket.
                let ticket = pipe.submit_hedged(&sc.pool.sharded, &self.pool);
                pending[layer] = PendingPrefetch::InFlight { ticket };
            }
        }
        Ok(true)
    }

    /// Settle `layer`'s in-flight prefetch right before its weights are
    /// consumed. Returns 1 if a submission was pending (the caller's
    /// in-flight counter decrements), 0 otherwise.
    ///
    /// Accounting charges only what compute could not hide: for virtual
    /// clocks, the time remaining until the read's device-serial
    /// analytic completion — the stage pays `max(compute, io)` with
    /// queued reads serializing on the virtual device (a single pool
    /// cannot serve N in-flight layers at N× bandwidth); for wall-clock
    /// tickets, the time this call actually blocked waiting. The hidden
    /// remainder lands in `overlapped_io`.
    #[allow(clippy::too_many_arguments)]
    fn consume_pending(
        &self,
        state: &mut SessionState,
        sc: &mut ScratchArena,
        layer: usize,
        stats: &mut StageStats,
        prefetch_service: &mut Duration,
        vclock: &mut VirtualClock,
    ) -> Result<u64> {
        match std::mem::take(&mut state.pending[layer]) {
            PendingPrefetch::Idle => Ok(0),
            PendingPrefetch::Virtual {
                completion,
                service,
            } => {
                // Remaining time until the device-serial analytic finish,
                // measured from the analytic now (wall clock + stalls
                // already charged this call, which nothing actually slept
                // through).
                let charged = completion.saturating_duration_since(vclock.now());
                vclock.stall += charged;
                stats.io += charged;
                stats.overlapped_io += service.saturating_sub(charged);
                *prefetch_service += service;
                Ok(1)
            }
            PendingPrefetch::InFlight { ticket } => {
                let slot = &mut state.prefetch[layer];
                sc.pool.last.reset(self.pool.len());
                let wait_t0 = Instant::now();
                let waited = ticket.wait_scatter(&mut slot.receipt.bytes, &mut sc.pool.last);
                let service = match waited {
                    Ok(d) => d,
                    Err(e) => {
                        slot.clear();
                        return Err(e);
                    }
                };
                let blocked = wait_t0.elapsed();
                slot.receipt.service = service;
                sc.pool.accum.absorb(&sc.pool.last);
                stats.io += blocked;
                stats.overlapped_io += service.saturating_sub(blocked);
                *prefetch_service += service;
                Ok(1)
            }
        }
    }

    /// Submit one logical plan through the storage pool. Single-member
    /// pools delegate straight to the member (bit-identical to the
    /// historical one-device path, now with retries); larger pools run
    /// the [`crate::plan::IoPlanner::shard_into`] step — or the
    /// replica-routed [`crate::storage::DevicePool::route_plan`] when
    /// hot stripes are
    /// replicated or a member is dead — and fan the sub-plans out across
    /// members, reassembling the logical receipt. Per-member
    /// bytes/service land in `ps.last` and accumulate into `ps.accum`
    /// for the per-call metrics fold. Allocation-free at steady state.
    pub(crate) fn submit_pooled(
        &self,
        plan: &ReadPlan,
        ps: &mut PoolScratch,
        receipt: &mut PlanReceipt,
    ) -> Result<()> {
        if self.pool.len() == 1 {
            // Single-member fast path with the pool's retry + liveness
            // accounting (bit-identical bytes; transient faults are
            // absorbed instead of failing the call).
            self.pool.submit_member_into(0, plan, receipt)?;
            ps.last.reset(1);
            ps.last.bytes[0] = plan.cmd_bytes();
            ps.last.service[0] = receipt.service;
        } else {
            if self.pool.needs_routing() {
                self.pool.route_plan(plan, &mut ps.sharded);
            } else {
                self.planner.shard_into(plan, self.pool.stripe(), &mut ps.sharded);
            }
            self.pool.submit_sharded_into(
                plan,
                &ps.sharded,
                &mut ps.staging,
                receipt,
                &mut ps.last,
            )?;
        }
        ps.accum.absorb(&ps.last);
        Ok(())
    }
}
