//! Chunked prefill: run a frame append a few layers at a time so the
//! scheduler worker can interleave decode batches mid-pass.
//!
//! A vision prefill is the long pole of the serving path — `t` tokens
//! through every layer, bandwidth-bound — while decode steps are short
//! and latency-bound. The monolithic driver parks a worker for the whole
//! pass; this driver splits the same pass at layer boundaries
//! ([`super::ForwardPass`] owns all loop state, so no lock or borrow
//! survives a pause) and lets the caller do other work between chunks.
//!
//! The invariant that makes this safe is the one the whole pipeline is
//! built on: pausing between layers changes **no** floating-point
//! computation. [`EngineCore::prefill_step`] runs the byte-for-byte same
//! layer body as [`EngineCore::forward`], so a chunked prefill's outputs
//! and KV caches are bit-identical to a monolithic append — only timing
//! stats observe the pause. The determinism tests pin this.
//!
//! Drivers must hold exclusive access to the session across the *whole*
//! pass (the scheduler's per-stream busy guard provides it); between
//! chunks every engine lock is released, so decode batches on other
//! sessions proceed under the shared read lock as usual. A pass left
//! unfinished (driver error, shed mid-pass) leaves half-appended KV
//! caches; the owner must reset the session before reuse —
//! [`crate::coordinator::Session`] does this automatically when it finds
//! an abandoned pass.

use anyhow::Result;

use super::{ForwardPass, StageStats};
use crate::coordinator::arena::ScratchArena;
use crate::coordinator::engine::EngineCore;
use crate::coordinator::pipeline::SessionState;

/// An in-progress chunked prefill pass: the owned forward-loop state plus
/// nothing else. Opaque outside the coordinator; held by the session
/// between chunks.
pub(crate) struct PrefillPass {
    pub(crate) pass: ForwardPass,
}

impl PrefillPass {
    /// Layers already run (monotonic; equals `spec.layers` when done).
    pub(crate) fn layers_done(&self) -> usize {
        self.pass.layer
    }

    pub(crate) fn done(&self) -> bool {
        self.pass.done()
    }
}

impl EngineCore {
    /// Begin a chunked prefill of a `t`-token frame. No layer runs yet.
    pub(crate) fn prefill_begin(
        &self,
        state: &mut SessionState,
        scratch: &mut ScratchArena,
        frame: &[f32],
        t: usize,
    ) -> PrefillPass {
        PrefillPass {
            pass: self.begin_pass(state, scratch, frame, t),
        }
    }

    /// Run up to `max_layers` more layers (at least one; `max_layers` of
    /// 0 is treated as 1). Returns `true` while layers remain — the
    /// caller may drop every lock and yield before the next step.
    pub(crate) fn prefill_step(
        &self,
        state: &mut SessionState,
        scratch: &mut ScratchArena,
        pp: &mut PrefillPass,
        max_layers: usize,
    ) -> Result<bool> {
        anyhow::ensure!(
            pp.pass.epoch == self.epoch,
            "engine re-calibrated mid-prefill (epoch {} -> {}); pass aborted",
            pp.pass.epoch,
            self.epoch
        );
        if pp.pass.layer > 0 {
            pp.pass.resumes += 1;
        }
        for _ in 0..max_layers.max(1) {
            if pp.pass.done() {
                break;
            }
            self.run_layer(state, scratch, &mut pp.pass)?;
        }
        Ok(!pp.pass.done())
    }

    /// Finish a completed pass: metrics fold + final activations.
    pub(crate) fn prefill_finish(
        &self,
        state: &mut SessionState,
        scratch: &mut ScratchArena,
        pp: PrefillPass,
        out: &mut Vec<f32>,
    ) -> StageStats {
        self.finish_pass(state, scratch, pp.pass, out)
    }
}
