//! Cross-stream batched decoding: the batch driver over the staged
//! pipeline.
//!
//! Concurrent decode requests on *different* sessions are driven through
//! the per-layer stages **stage-synchronously**: selection runs per
//! stream (so every stream's selected-chunk set is exactly what it would
//! pick solo), then the per-group flash plans are fused
//! ([`crate::plan::IoPlanner::fuse_into`]) so chunks demanded by more
//! than one stream are read from flash once and scattered to every
//! subscriber, and streams whose compute sets coincide form a *cohort*
//! that gathers one shared weight tile and runs the multi-stream kernels
//! ([`crate::runtime::XlaRuntime::execute_batched_into`]) across all
//! member activations in one dispatch. Per-layer prefetch submissions
//! are fused the same way.
//!
//! Two streams decoding the same layer often select overlapping hot
//! chunks (the paper's contiguity argument made cross-stream): the fused
//! plan reads each shared chunk once, so the deeper the batch, the fewer
//! bytes and commands per stream — `io.shared_bytes` and
//! `batch.occupancy` in the engine metrics track exactly that.
//!
//! **Determinism invariant**: every member's outputs and selected-chunk
//! sets are bit-identical to solo [`Session::decode_step`] calls on the
//! same session history — fusion changes which *submission* carries a
//! byte, never the byte; cohort kernels compute each stream's rows in
//! the solo reduction order. Batching is a pure throughput change.
//!
//! Batched decoding always drives the inline (synchronous) submission
//! path; on engines with wall-clock pools and async I/O the fused read
//! is routed through the per-member I/O workers as a single fused
//! ticket ([`crate::storage::IoTicket::wait_scatter_fused`]). Either
//! way the batch is validated member-by-member *before* any state
//! mutates, and a failure *after* validation (a device error mid-layer)
//! rolls every member's KV caches back to their pre-batch marks
//! ([`crate::coordinator::KvCache::mark_into`]) — a failed batch is
//! transactional, so the scheduler can retry its members solo. At
//! steady state batched decoding performs zero heap allocations (the
//! batch arena, marks included, is pooled in the engine core).

use std::sync::MutexGuard;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::engine::{EngineCore, Session, SessionInner};
use crate::coordinator::pipeline::StageStats;
use crate::coordinator::{KvMark, StageTimer};
use crate::model::{MatrixId, MatrixKind};
use crate::plan::{FuseScratch, FusedPlan, PlanReceipt, PlannedRead, ReadPlan};
use crate::runtime::{ExecScratch, StageOutputs, StreamCtx, TensorView};
use crate::storage::PoolScratch;

/// Ceiling on the members of one fused decode batch. Schedulers clamp
/// their window to this; it bounds the driver's stack-allocated
/// bookkeeping so batch formation never allocates.
pub const MAX_DECODE_BATCH: usize = 16;

/// One member of a decode batch: a session plus the token to decode.
pub struct DecodeRequest<'a> {
    pub session: &'a Session,
    pub token: &'a [f32],
}

/// Batch-level working memory: fusion scratch, the fused plan/receipt,
/// pool fan-out buffers, and the cohort kernels' stacked activations and
/// outputs. Pooled in the engine core's free list, so steady-state
/// batched decoding reuses capacity instead of allocating.
#[derive(Default)]
pub(crate) struct BatchArena {
    /// Fusion working memory (the plan layer's [`FuseScratch`]).
    fuse: FuseScratch,
    /// Fused union plan + subscriber scatter map of the current step.
    fused: FusedPlan,
    /// Receipt of the fused submission (inline scatter path).
    receipt: PlanReceipt,
    /// Pool fan-out scratch + per-batch per-member I/O accounting.
    pool: PoolScratch,
    /// Stacked activations `[n, bucket]` of one cohort.
    xs: Vec<f32>,
    exec: ExecScratch,
    outs: StageOutputs,
    /// Per-member, per-layer KV rollback marks captured before the
    /// batch mutates anything (decode appends exactly one token per
    /// layer cache, so each mark covers a one-slot window).
    kv_marks: Vec<Vec<KvMark>>,
}

/// Which pooled [`PlannedRead`] a fused submission scatters into.
#[derive(Clone, Copy)]
enum FuseTarget {
    /// The per-group fresh read (`scratch.gather.fresh`).
    Fresh,
    /// A layer's prefetch slot (`state.prefetch[layer]`).
    Prefetch(usize),
}

fn target_read<'x>(inner: &'x mut SessionInner, target: FuseTarget) -> &'x mut PlannedRead {
    match target {
        FuseTarget::Fresh => &mut inner.scratch.gather.fresh,
        FuseTarget::Prefetch(layer) => &mut inner.state.prefetch[layer],
    }
}

impl EngineCore {
    pub(crate) fn take_batch_arena(&self) -> Box<BatchArena> {
        self.batch_arenas.lock().unwrap().pop().unwrap_or_default()
    }

    pub(crate) fn put_batch_arena(&self, bs: Box<BatchArena>) {
        self.batch_arenas.lock().unwrap().push(bs);
    }

    /// Pre-reserve the batch arena's worst-case capacities for an
    /// `n`-member batch. Like the session-buffer reserves, this bounds
    /// every selection-shape-dependent buffer, so once a batch of a
    /// given size has warmed the arena, further batches allocate
    /// nothing (`reserve` is a no-op when capacity suffices).
    fn reserve_batch(&self, n: usize, bs: &mut BatchArena) {
        let spec = &self.spec;
        let n_max = spec.d.max(spec.h);
        let max_chunks = n_max / 2 + 1;
        // A whole prefetched layer (all 7 matrices) per member is the
        // worst single fusion.
        let member_cmds = 7 * max_chunks;
        let mut layer_bytes = 0usize;
        for kind in MatrixKind::SCORED {
            for member in MatrixKind::ALL {
                if member.mask_source() == kind {
                    layer_bytes += spec.shape_of(member).rows
                        * self.store.layout.row_bytes(MatrixId::new(0, member));
                }
            }
        }
        bs.fuse.reserve(n * member_cmds);
        bs.fused.reserve(n * member_cmds);
        bs.receipt.reserve(n * layer_bytes, n * member_cmds);
        let pool_cmds = n * member_cmds + self.pool.stripe().num_blocks() + 1;
        bs.pool.reserve(self.pool.len(), pool_cmds, n * layer_bytes);
        bs.xs.reserve(n * n_max);
        for o in &mut bs.outs.out {
            o.reserve(n * n_max);
        }
        bs.exec
            .reserve(n, spec.d, spec.h, spec.cache_slots, self.meta.nh);
    }
}

/// Decode one token on every member session cooperatively. See the
/// module docs for the driver's structure and invariants. Called with
/// the engine core's read lock held.
pub(crate) fn decode_batch(
    core: &EngineCore,
    reqs: &[DecodeRequest],
    outs: &mut [Vec<f32>],
    stats_out: &mut [StageStats],
) -> Result<()> {
    let n = reqs.len();
    anyhow::ensure!(n >= 1, "decode batch needs at least one member");
    anyhow::ensure!(
        n <= MAX_DECODE_BATCH,
        "decode batch of {n} exceeds MAX_DECODE_BATCH ({MAX_DECODE_BATCH})"
    );
    anyhow::ensure!(
        outs.len() == n && stats_out.len() == n,
        "decode batch outputs/stats slices must match the request count"
    );
    let d = core.meta.d;
    for (i, r) in reqs.iter().enumerate() {
        anyhow::ensure!(r.token.len() == d, "batch member {i}: token must be [d={d}]");
    }

    // Deadlock-free locking: acquire the session locks in address order
    // (concurrent batches over overlapping session sets then always lock
    // in the same global order); a session may appear at most once.
    let mut order: [usize; MAX_DECODE_BATCH] = [0; MAX_DECODE_BATCH];
    for (i, o) in order.iter_mut().enumerate().take(n) {
        *o = i;
    }
    order[..n].sort_unstable_by_key(|&i| reqs[i].session as *const Session as usize);
    for w in order[..n].windows(2) {
        anyhow::ensure!(
            !std::ptr::eq(reqs[w[0]].session, reqs[w[1]].session),
            "decode batch contains the same session twice"
        );
    }
    let mut guards: [Option<MutexGuard<SessionInner>>; MAX_DECODE_BATCH] =
        std::array::from_fn(|_| None);
    for &i in &order[..n] {
        guards[i] = Some(reqs[i].session.inner.lock().unwrap());
    }
    let mut members: [Option<&mut SessionInner>; MAX_DECODE_BATCH] =
        std::array::from_fn(|_| None);
    for (slot, g) in members.iter_mut().zip(guards.iter_mut()).take(n) {
        *slot = Some(&mut **g.as_mut().expect("guard held for every member"));
    }
    let members = &mut members[..n];

    // Validate every member's decode preconditions (mirroring the solo
    // path) *before* any state mutates: a batch starts on all members or
    // on none, so an invalid member cannot poison the others.
    for (i, m) in members.iter().enumerate() {
        let inner = m.as_ref().expect("member slot filled");
        let ok = inner.state.epoch == core.epoch
            && inner.state.kvs.iter().any(|kv| !kv.is_empty());
        anyhow::ensure!(
            ok,
            "batch member {i}: decode requires a non-empty KV cache (append a frame first)"
        );
    }

    let mut bs = core.take_batch_arena();
    // Transactional decode: mark every member's per-layer KV ring
    // before the pipeline mutates anything. A decode step appends
    // exactly one token per layer cache, so one-slot marks cover every
    // append a failed run could have made.
    if bs.kv_marks.len() < n {
        bs.kv_marks.resize_with(n, Vec::new);
    }
    for (i, m) in members.iter().enumerate() {
        let inner = m.as_ref().expect("member slot filled");
        let marks = &mut bs.kv_marks[i];
        if marks.len() < inner.state.kvs.len() {
            marks.resize_with(inner.state.kvs.len(), KvMark::default);
        }
        for (kv, mark) in inner.state.kvs.iter().zip(marks.iter_mut()) {
            kv.mark_into(1, mark);
        }
    }
    let result = run_batch(core, members, reqs, outs, stats_out, &mut bs);
    if result.is_err() {
        // Roll every member back: a failed batch leaves no session
        // partially advanced (callers may retry members solo).
        for (i, m) in members.iter_mut().enumerate() {
            let inner = m.as_mut().expect("member slot filled");
            for (kv, mark) in inner.state.kvs.iter_mut().zip(bs.kv_marks[i].iter()) {
                kv.rollback(mark);
            }
        }
    }
    core.put_batch_arena(bs);
    result
}

fn run_batch(
    core: &EngineCore,
    members: &mut [Option<&mut SessionInner>],
    reqs: &[DecodeRequest],
    outs: &mut [Vec<f32>],
    stats_out: &mut [StageStats],
    bs: &mut BatchArena,
) -> Result<()> {
    let n = members.len();
    let layers = core.spec.layers;
    let t = 1usize;
    core.reserve_batch(n, bs);
    bs.pool.accum.reset(core.pool.len());
    let mut shared_bytes = 0u64;
    let mut prefetch_service = Duration::ZERO;
    let mut buckets: [usize; MAX_DECODE_BATCH] = [0; MAX_DECODE_BATCH];

    // Per-member call preamble (mirrors the solo driver's).
    for (i, m) in members.iter_mut().enumerate() {
        let inner = m.as_mut().expect("member slot filled");
        // Batched decoding drives the inline submission path; settle any
        // prefetch a previous aborted async call left in flight first.
        inner.state.drain_stale();
        let sc = &mut inner.scratch;
        sc.fwd.xa.clear();
        sc.fwd.xa.extend_from_slice(reqs[i].token);
        stats_out[i] = StageStats::default();
    }

    for layer in 0..layers {
        let layer_t0 = Instant::now();
        // Swap each member's prefetched whole-layer read into its arena.
        for m in members.iter_mut() {
            let inner = m.as_mut().expect("member slot filled");
            let SessionInner { state, scratch, .. } = &mut **inner;
            std::mem::swap(&mut scratch.pre, &mut state.prefetch[layer]);
            state.prefetch[layer].clear();
        }

        for group in 0..4 {
            let kind = MatrixKind::SCORED[group];
            // --- per-stream: normalize → score → select → plan ---
            for (i, m) in members.iter_mut().enumerate() {
                let inner = m.as_mut().expect("member slot filled");
                let SessionInner { state, scratch: sc, .. } = &mut **inner;
                let stats = &mut stats_out[i];
                core.score_group(group, t, &mut sc.fwd, stats);
                core.select_into(
                    layer,
                    kind,
                    &sc.fwd.imp,
                    stats,
                    &mut sc.sel_scratch,
                    &mut sc.imp_phys,
                    &mut sc.sel,
                );
                let acts: &[f32] = match group {
                    0 | 2 => &sc.fwd.hn,
                    1 => &sc.fwd.attn,
                    _ => &sc.fwd.act,
                };
                let pre = if sc.pre.is_empty() { None } else { Some(&sc.pre) };
                buckets[i] = core.prepare_group_load(
                    layer,
                    kind,
                    acts,
                    t,
                    &sc.sel,
                    pre,
                    &mut sc.gather,
                    &mut sc.plan_scratch,
                    stats,
                );
                let dst = &mut state.next_masks[layer][group];
                dst.clear();
                dst.extend_from_slice(&sc.gather.flash_chunks);
            }

            // --- cohort streams with identical compute sets; the lead
            //     gathers the shared weight tile once, so only lead
            //     demand needs to touch flash at all ---
            let mut cohort_of: [usize; MAX_DECODE_BATCH] = [usize::MAX; MAX_DECODE_BATCH];
            for i in 0..n {
                if cohort_of[i] != usize::MAX {
                    continue;
                }
                cohort_of[i] = i;
                for j in (i + 1)..n {
                    if cohort_of[j] != usize::MAX {
                        continue;
                    }
                    let a = &members[i]
                        .as_ref()
                        .expect("member slot filled")
                        .scratch
                        .gather
                        .phys_rows;
                    let b = &members[j]
                        .as_ref()
                        .expect("member slot filled")
                        .scratch
                        .gather
                        .phys_rows;
                    if a == b {
                        cohort_of[j] = i;
                    }
                }
            }

            // --- fuse the cohort leads' fresh plans into one submission.
            // Followers share their lead's compute set, and the weight
            // tile is gathered once from the lead's sources, so follower
            // demand never needs to be read (or scattered) at all —
            // their whole planned read counts as deduped.
            let mut followers: [bool; MAX_DECODE_BATCH] = [false; MAX_DECODE_BATCH];
            {
                let empty = ReadPlan::default();
                let mut plans: [&ReadPlan; MAX_DECODE_BATCH] = [&empty; MAX_DECODE_BATCH];
                for (i, slot) in plans.iter_mut().enumerate().take(n) {
                    let plan = &members[i]
                        .as_ref()
                        .expect("member slot filled")
                        .scratch
                        .gather
                        .fresh
                        .plan;
                    if cohort_of[i] == i {
                        *slot = plan;
                    } else {
                        followers[i] = true;
                        shared_bytes += plan.cmd_bytes();
                    }
                }
                core.planner
                    .fuse_into(&plans[..n], Some(&core.table), &mut bs.fuse, &mut bs.fused);
            }
            let service = if bs.fused.is_empty() {
                Duration::ZERO
            } else {
                shared_bytes += bs.fused.shared_bytes();
                submit_fused(core, members, FuseTarget::Fresh, &followers[..n], bs)
                    .with_context(|| format!("batched group read (layer {layer})"))?
            };
            for (i, m) in members.iter_mut().enumerate() {
                let inner = m.as_mut().expect("member slot filled");
                let fresh = &inner.scratch.gather.fresh;
                if !fresh.plan.is_empty() {
                    // Accounting mirrors a solo decode: the stream's own
                    // demanded payload, charged the fused submission's
                    // service (the batch shares one device pass).
                    stats_out[i].bytes_loaded += fresh.plan.payload_bytes();
                    stats_out[i].io += service;
                }
            }

            for i in 0..n {
                if cohort_of[i] != i {
                    continue;
                }
                let inner = members[i].as_mut().expect("member slot filled");
                let SessionInner { state: _, scratch: sc, .. } = &mut **inner;
                let pre = if sc.pre.is_empty() { None } else { Some(&sc.pre) };
                core.gather_group_weights(
                    layer,
                    kind,
                    buckets[i],
                    pre,
                    &mut sc.gather,
                    &mut stats_out[i],
                );
            }

            // --- execute: one multi-stream dispatch per cohort ---
            for lead in 0..n {
                if cohort_of[lead] != lead {
                    continue;
                }
                let size = cohort_of[..n].iter().filter(|&&c| c == lead).count();
                if size == 1 {
                    let inner = members[lead].as_mut().expect("member slot filled");
                    let SessionInner { state, scratch: sc, .. } = &mut **inner;
                    core.exec_group_solo(
                        group,
                        t,
                        buckets[lead],
                        &mut state.kvs[layer],
                        &sc.gather,
                        &mut sc.fwd,
                        &mut sc.exec,
                        &mut sc.outs,
                        &mut stats_out[lead],
                    )?;
                } else {
                    exec_cohort(
                        core, members, &cohort_of, lead, size, group, buckets[lead], layer,
                        bs, stats_out,
                    )?;
                }
            }
        }

        // --- fused prefetch of layer l+1 (inline path) ---
        if core.prefetch && layer + 1 < layers {
            let mut any = false;
            for m in members.iter_mut() {
                let inner = m.as_mut().expect("member slot filled");
                let SessionInner { state, scratch: sc, .. } = &mut **inner;
                any |= core.plan_layer_prefetch(state, &mut sc.plan_scratch, layer + 1);
            }
            if any {
                {
                    let empty = ReadPlan::default();
                    let mut plans: [&ReadPlan; MAX_DECODE_BATCH] = [&empty; MAX_DECODE_BATCH];
                    for (i, slot) in plans.iter_mut().enumerate().take(n) {
                        *slot = &members[i]
                            .as_ref()
                            .expect("member slot filled")
                            .state
                            .prefetch[layer + 1]
                            .plan;
                    }
                    core.planner.fuse_into(
                        &plans[..n],
                        Some(&core.table),
                        &mut bs.fuse,
                        &mut bs.fused,
                    );
                }
                shared_bytes += bs.fused.shared_bytes();
                // Every member keeps (and needs) its own prefetch
                // buffer, so prefetch fusion has no followers.
                let no_followers = [false; MAX_DECODE_BATCH];
                let target = FuseTarget::Prefetch(layer + 1);
                let service = match submit_fused(core, members, target, &no_followers[..n], bs)
                {
                    Ok(s) => s,
                    Err(e) => {
                        // Never leave a non-empty plan over an unfilled
                        // receipt: the next call would swap the slot in
                        // and serve garbage bytes.
                        for m in members.iter_mut() {
                            m.as_mut().expect("member slot filled").state.prefetch[layer + 1]
                                .clear();
                        }
                        return Err(e);
                    }
                };
                let overlap = layer_t0.elapsed();
                for (i, m) in members.iter_mut().enumerate() {
                    let inner = m.as_mut().expect("member slot filled");
                    let slot = &inner.state.prefetch[layer + 1];
                    if slot.plan.is_empty() {
                        continue;
                    }
                    let payload = slot.plan.payload_bytes();
                    let charged = service.saturating_sub(overlap);
                    stats_out[i].io += charged;
                    stats_out[i].overlapped_io += service - charged;
                    stats_out[i].bytes_loaded += payload;
                    stats_out[i].prefetched_bytes += payload;
                }
                prefetch_service += service;
            }
        }
    }

    // Per-member call epilogue + outputs.
    for (i, m) in members.iter_mut().enumerate() {
        let inner = m.as_mut().expect("member slot filled");
        let SessionInner { state, scratch: sc, .. } = &mut **inner;
        std::mem::swap(&mut state.prev_masks, &mut state.next_masks);
        outs[i].clear();
        outs[i].extend_from_slice(&sc.fwd.xa);
    }

    // One metrics fold for the whole batch (keys are literals or
    // pre-rendered, so this allocates nothing once warm).
    {
        let mut host = Duration::ZERO;
        let mut select = Duration::ZERO;
        let mut compute = Duration::ZERO;
        let mut io = Duration::ZERO;
        let mut overlapped = Duration::ZERO;
        let mut bytes = 0u64;
        let mut cache_hit_bytes = 0u64;
        for s in stats_out.iter() {
            host += s.host;
            select += s.select;
            compute += s.compute;
            io += s.io;
            overlapped += s.overlapped_io;
            bytes += s.bytes_loaded;
            cache_hit_bytes += s.cache_hit_bytes;
        }
        let mut metrics = core.metrics.lock().unwrap();
        metrics.add("host", host);
        metrics.add("select", select);
        metrics.add("compute", compute);
        metrics.add("io", io);
        if prefetch_service > Duration::ZERO {
            metrics.add("prefetch", prefetch_service);
            metrics.add("io.overlapped", overlapped);
        }
        metrics.add_bytes("io", bytes);
        metrics.add_bytes(core.io_dtype_bytes, bytes);
        if cache_hit_bytes > 0 {
            metrics.add_bytes("io.cache_hit_bytes", cache_hit_bytes);
        }
        // Fusion accounting: bytes the batch read once instead of once
        // per subscriber (the dedup ratio is shared / (shared + io
        // bytes)), and the achieved batch occupancy (bytes = Σ members,
        // count = batches → average members per batch).
        metrics.add_bytes("io.shared_bytes", shared_bytes);
        metrics.add("batch.occupancy", Duration::ZERO);
        metrics.add_bytes("batch.occupancy", n as u64);
        if core.pool.len() > 1 {
            for m in 0..core.pool.len() {
                metrics.add(&core.dev_io_names[m], bs.pool.accum.service[m]);
                metrics.add_bytes(&core.dev_io_names[m], bs.pool.accum.bytes[m]);
            }
        }
    }
    Ok(())
}

/// Submit the fused union plan once and scatter its bytes into every
/// subscriber's target receipt; sets each non-empty subscriber's receipt
/// service to the fused submission's service and returns it. Members
/// flagged in `followers` were excluded from the fusion (their cohort
/// lead's tile serves them) — their receipts are cleared, never filled.
fn submit_fused(
    core: &EngineCore,
    members: &mut [Option<&mut SessionInner>],
    target: FuseTarget,
    followers: &[bool],
    bs: &mut BatchArena,
) -> Result<Duration> {
    let n = members.len();
    // Pre-size every subscriber receipt for its own plan layout (the
    // same layout a solo submission would produce).
    for (i, m) in members.iter_mut().enumerate() {
        let inner = m.as_mut().expect("member slot filled");
        let PlannedRead { plan, receipt } = target_read(inner, target);
        if plan.is_empty() || followers[i] {
            receipt.clear();
        } else {
            receipt.presize_for(plan.cmds());
        }
    }
    let service = match &core.async_pipe {
        Some(pipe) => {
            // Wall-clock pools: one fused ticket reads the union on the
            // per-member I/O workers and scatters straight into the N
            // subscriber receipts. Replicated/degraded pools route each
            // piece to a live replica and arm hedged completion.
            if core.pool.needs_routing() {
                core.pool.route_plan(&bs.fused.plan, &mut bs.pool.sharded);
            } else {
                core.planner
                    .shard_into(&bs.fused.plan, core.pool.stripe(), &mut bs.pool.sharded);
            }
            let total: usize = bs.fused.plan.cmds().iter().map(|e| e.len).sum();
            anyhow::ensure!(
                bs.pool.sharded.total_bytes() == total,
                "sharded fused plan covers {} of {total} bytes",
                bs.pool.sharded.total_bytes()
            );
            let ticket = pipe.submit_hedged(&bs.pool.sharded, &core.pool);
            bs.pool.last.reset(core.pool.len());
            let mut slices: [&mut [u8]; MAX_DECODE_BATCH] =
                std::array::from_fn(|_| Default::default());
            for (slot, m) in slices.iter_mut().zip(members.iter_mut()) {
                let inner = m.as_mut().expect("member slot filled");
                *slot = &mut target_read(inner, target).receipt.bytes[..];
            }
            let service =
                ticket.wait_scatter_fused(&bs.fused, &mut slices[..n], &mut bs.pool.last)?;
            bs.pool.accum.absorb(&bs.pool.last);
            service
        }
        None => {
            // Inline path: submit the union through the pool into the
            // batch receipt, then copy each subscriber its bytes.
            core.submit_pooled(&bs.fused.plan, &mut bs.pool, &mut bs.receipt)?;
            for (i, m) in members.iter_mut().enumerate() {
                let inner = m.as_mut().expect("member slot filled");
                let bytes = &mut target_read(inner, target).receipt.bytes;
                for c in bs.fused.copies.iter().filter(|c| c.stream == i) {
                    bytes[c.dst..c.dst + c.len]
                        .copy_from_slice(&bs.receipt.bytes[c.src..c.src + c.len]);
                }
            }
            bs.receipt.service
        }
    };
    for (i, m) in members.iter_mut().enumerate() {
        if followers[i] {
            continue;
        }
        let inner = m.as_mut().expect("member slot filled");
        let read = target_read(inner, target);
        if !read.plan.is_empty() {
            read.receipt.service = service;
        }
    }
    Ok(service)
}

/// Run one group's stage artifact for a cohort of `size > 1` streams
/// that share the lead's gathered weight tile: stack the members'
/// activation rows, dispatch the multi-stream kernel once, then scatter
/// each stream's output rows back into its own forward buffers (and
/// append K/V for the attention group).
#[allow(clippy::too_many_arguments)]
fn exec_cohort(
    core: &EngineCore,
    members: &mut [Option<&mut SessionInner>],
    cohort_of: &[usize; MAX_DECODE_BATCH],
    lead: usize,
    size: usize,
    group: usize,
    bucket: usize,
    layer: usize,
    bs: &mut BatchArena,
    stats_out: &mut [StageStats],
) -> Result<()> {
    let n = members.len();
    let d = core.meta.d;
    let h = core.meta.h;

    // Stack the cohort's activation rows [size, bucket].
    bs.xs.clear();
    for i in 0..n {
        if cohort_of[i] != lead {
            continue;
        }
        bs.xs.extend_from_slice(
            &members[i]
                .as_ref()
                .expect("member slot filled")
                .scratch
                .gather
                .xs,
        );
    }

    let timer = StageTimer::start();
    {
        // Per-stream operands (KV views / residual rows) + the lead's
        // shared weight tile; all shared borrows, released before the
        // write-back below.
        let mut streams: [StreamCtx; MAX_DECODE_BATCH] = [StreamCtx::default(); MAX_DECODE_BATCH];
        let mut si = 0usize;
        for i in 0..n {
            if cohort_of[i] != lead {
                continue;
            }
            let inner = members[i].as_ref().expect("member slot filled");
            streams[si] = match group {
                0 => {
                    let (kc, vc, kmask) = inner.state.kvs[layer].views();
                    StreamCtx {
                        kc,
                        vc,
                        kmask,
                        ..StreamCtx::default()
                    }
                }
                1 => StreamCtx {
                    residual: &inner.scratch.fwd.xa,
                    ..StreamCtx::default()
                },
                3 => StreamCtx {
                    residual: &inner.scratch.fwd.xb,
                    ..StreamCtx::default()
                },
                _ => StreamCtx::default(),
            };
            si += 1;
        }
        let lead_g = &members[lead]
            .as_ref()
            .expect("member slot filled")
            .scratch
            .gather;
        let (base, n_weights, cols) = match group {
            0 => ("qkv", 3usize, d),
            1 | 3 => ("projres", 1, d),
            _ => ("gateup", 2, h),
        };
        let name = core.artifact_name(base, 1, bucket)?;
        // Pad unused slots with the first tile (only the first
        // `n_weights` views are passed on).
        let weights: [TensorView; 3] = [
            TensorView::mat(bucket, cols, &lead_g.weights[0]),
            if n_weights > 1 {
                TensorView::mat(bucket, cols, &lead_g.weights[1])
            } else {
                TensorView::mat(bucket, cols, &lead_g.weights[0])
            },
            if n_weights > 2 {
                TensorView::mat(bucket, cols, &lead_g.weights[2])
            } else {
                TensorView::mat(bucket, cols, &lead_g.weights[0])
            },
        ];
        core.runtime.execute_batched_into(
            name,
            &bs.xs,
            &weights[..n_weights],
            &streams[..size],
            core.exec_threads,
            &mut bs.exec,
            &mut bs.outs,
        )?;
    }
    let shared_compute = timer.finish();

    // Scatter output rows back per member + post-exec updates.
    let mut si = 0usize;
    for i in 0..n {
        if cohort_of[i] != lead {
            continue;
        }
        let inner = members[i].as_mut().expect("member slot filled");
        let SessionInner { state, scratch: sc, .. } = &mut **inner;
        match group {
            0 => {
                sc.fwd.attn.clear();
                sc.fwd.attn
                    .extend_from_slice(&bs.outs.out[0][si * d..(si + 1) * d]);
                state.kvs[layer].append(
                    &bs.outs.out[1][si * d..(si + 1) * d],
                    &bs.outs.out[2][si * d..(si + 1) * d],
                );
            }
            1 => {
                sc.fwd.xb.clear();
                sc.fwd.xb
                    .extend_from_slice(&bs.outs.out[0][si * d..(si + 1) * d]);
            }
            2 => {
                sc.fwd.act.clear();
                sc.fwd.act
                    .extend_from_slice(&bs.outs.out[0][si * h..(si + 1) * h]);
            }
            _ => {
                sc.fwd.xa.clear();
                sc.fwd.xa
                    .extend_from_slice(&bs.outs.out[0][si * d..(si + 1) * d]);
            }
        }
        // Each member observes the cohort's shared dispatch wall time.
        stats_out[i].compute += shared_compute;
        si += 1;
    }
    Ok(())
}
