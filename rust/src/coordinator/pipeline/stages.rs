//! The individual pipeline stages: normalize/score, select, plan,
//! gather, execute. Both serving drivers compose exactly these helpers —
//! [`EngineCore::forward`](crate::coordinator::engine::EngineCore) runs
//! them for one stream, the batch driver runs them stage-synchronously
//! across a whole decode batch — which is what makes the solo/batched
//! bit-identity invariant auditable: the per-stream math lives in one
//! place.

use anyhow::Result;

use crate::coordinator::arena::{FwdBufs, GatherScratch};
use crate::coordinator::engine::EngineCore;
use crate::coordinator::pipeline::StageStats;
use crate::coordinator::{KvCache, StageTimer};
use crate::latency::Chunk;
use crate::model::{decode_row_into, MatrixId, MatrixKind};
use crate::plan::{PlanScratch, PlannedRead, RowCursor};
use crate::runtime::{ExecScratch, ModelMeta, StageOutputs, TensorView};
use crate::sparsify::{SelectScratch, SelectionMask};

/// The member matrices of the selection group led by a scored `kind`
/// (K/V reuse Q's mask, Up reuses Gate's — they share input activations).
pub(crate) fn group_members(kind: MatrixKind) -> &'static [MatrixKind] {
    match kind {
        MatrixKind::Q => &[MatrixKind::Q, MatrixKind::K, MatrixKind::V],
        MatrixKind::O => &[MatrixKind::O],
        MatrixKind::Gate => &[MatrixKind::Gate, MatrixKind::Up],
        MatrixKind::Down => &[MatrixKind::Down],
        _ => unreachable!("only scored kinds lead a group"),
    }
}

impl EngineCore {
    /// Stage 1 — normalize/score: RMS-norm the stage input where the
    /// reference model does and reduce it to per-column importance
    /// (`fwd.imp`), per selection group.
    pub(crate) fn score_group(
        &self,
        group: usize,
        t: usize,
        fwd: &mut FwdBufs,
        stats: &mut StageStats,
    ) {
        let d = self.meta.d;
        let h = self.meta.h;
        let timer = StageTimer::start();
        match group {
            0 => {
                rmsnorm_into(&fwd.xa, t, d, &mut fwd.hn);
                col_importance_into(&fwd.hn, t, d, &mut fwd.imp);
            }
            1 => col_importance_into(&fwd.attn, t, d, &mut fwd.imp),
            2 => {
                rmsnorm_into(&fwd.xb, t, d, &mut fwd.hn);
                col_importance_into(&fwd.hn, t, d, &mut fwd.imp);
            }
            _ => col_importance_into(&fwd.act, t, h, &mut fwd.imp),
        }
        stats.host += timer.finish();
    }

    /// Stage 2 — select: run the selection policy for one scored matrix,
    /// writing the mask into `out` (arena-backed; no allocations at
    /// steady state).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn select_into(
        &self,
        layer: usize,
        kind: MatrixKind,
        importance_logical: &[f32],
        stats: &mut StageStats,
        scratch: &mut SelectScratch,
        imp_phys: &mut Vec<f32>,
        out: &mut SelectionMask,
    ) {
        let rows = importance_logical.len();
        let timer = StageTimer::start();
        // Move importance into physical (reordered) row space.
        let id = MatrixId::new(layer, kind);
        match self.store.permutation(id) {
            Some(p) => p.apply_into(importance_logical, imp_phys),
            None => {
                imp_phys.clear();
                imp_phys.extend_from_slice(importance_logical);
            }
        }
        let total: f64 = imp_phys.iter().map(|&v| v as f64).sum();
        // Cached rows are free: zero their importance pre-selection (§5).
        if let Some(cache) = &self.neuron_cache {
            cache.zero_cached(id, imp_phys);
        }
        // Chunk-cache pricing mode (§5 semantics on the live cache):
        // resident rows cost nothing, so their importance is zeroed too —
        // the selector-side equivalent of a near-zero latency estimate in
        // the importance ÷ latency utility. The freed mass is credited
        // back to `importance_kept` below (the rows still compute, served
        // from RAM). Default mode returns 0.0 without touching anything.
        let mut cache_freed = 0.0f64;
        if let Some(cache) = &self.chunk_cache {
            let gi = crate::coordinator::pipeline::group_index(kind);
            cache_freed = cache.zero_resident(layer, gi, imp_phys);
        }
        let budget = ((1.0 - self.sparsity) * rows as f64).round() as usize;
        match &self.selector {
            None => out.set_full(rows),
            Some(s) => {
                // Price chunks at the *encoded* on-flash row width: a
                // quantized image shrinks the latency denominator of the
                // utility exactly as it shrinks the bytes a read costs.
                let row_bytes = self.store.layout.row_bytes(id);
                let table = self
                    .keyed_tables
                    .get(&row_bytes)
                    .expect("table pre-keyed for every scored row size");
                s.select_into(imp_phys, budget, table, scratch, out);
            }
        }
        stats.select += timer.finish();
        stats.importance_total += total;
        stats.importance_kept += out.captured_importance(imp_phys);
        if let Some(cache) = &self.neuron_cache {
            stats.importance_kept +=
                cache.cached_importance(id, importance_logical, self.store.permutation(id));
        }
        stats.importance_kept += cache_freed;
    }

    /// Stage 3 — plan: build the group's compute set (selected ∪ cached
    /// rows), gather the matching activation columns padded to the
    /// compiled bucket, subtract what the layer prefetch buffer already
    /// holds, and plan the residual demand as one cross-matrix command
    /// batch into `g.fresh.plan` (not yet submitted). Returns the
    /// compiled bucket size. Allocation-free.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn prepare_group_load(
        &self,
        layer: usize,
        kind: MatrixKind,
        acts: &[f32],
        t: usize,
        sel: &SelectionMask,
        prefetched: Option<&PlannedRead>,
        g: &mut GatherScratch,
        plan_scratch: &mut PlanScratch,
        stats: &mut StageStats,
    ) -> usize {
        let members = group_members(kind);
        let in_rows = self.spec.shape_of(kind).rows;

        // Union of selected + cached rows (sorted, physical space).
        let id0 = MatrixId::new(layer, kind);
        g.phys_rows.clear();
        for chunk in &sel.chunks {
            g.phys_rows.extend(chunk.start..chunk.end());
        }
        g.flash_chunks.clear();
        g.flash_chunks.extend_from_slice(&sel.chunks);
        if let Some(cache) = &self.neuron_cache {
            let cached = cache.cached_rows(id0);
            if !cached.is_empty() {
                g.selset.clear();
                g.selset.resize(in_rows, false);
                for &r in g.phys_rows.iter() {
                    g.selset[r] = true;
                }
                for &r in cached {
                    if !g.selset[r] {
                        g.phys_rows.push(r);
                    }
                }
                g.phys_rows.sort_unstable();
                // Flash reads exclude cached rows (arena-backed run
                // splitting; no per-chunk allocation).
                g.flash_chunks.clear();
                for chunk in &sel.chunks {
                    cache.subtract_cached_into(id0, *chunk, &mut g.flash_chunks);
                }
            }
        }

        // Shared chunk cache: record this step's demand (pre-subtraction,
        // so admission frequency reflects selection, not misses), then
        // subtract resident rows from the flash demand and stage their
        // weights from RAM — the I/O planner below only ever sees misses.
        // Default mode leaves `phys_rows` (the compute set) untouched;
        // pricing mode unions residents in (§5). One shard read lock,
        // arena buffers only.
        let mut cache_hit = 0u64;
        if let Some(cache) = &self.chunk_cache {
            let gi = crate::coordinator::pipeline::group_index(kind);
            cache.record_selection(layer, gi, &sel.chunks);
            if cache.pricing() {
                g.selset.clear();
                g.selset.resize(in_rows, false);
                for &r in g.phys_rows.iter() {
                    g.selset[r] = true;
                }
            }
            cache_hit = cache.prepare(
                layer,
                gi,
                &mut g.phys_rows,
                &mut g.selset,
                &mut g.flash_chunks,
                &mut g.cache_tmp,
                &mut g.cache_rows,
                &mut g.cache_data,
            );
        } else {
            g.cache_rows.clear();
        }
        stats.cache_hit_bytes += cache_hit;

        let buckets = if kind == MatrixKind::Down {
            &self.meta.h_buckets
        } else {
            &self.meta.d_buckets
        };
        let bucket = ModelMeta::bucket_for(buckets, g.phys_rows.len());

        // Gather activations: xs[:, j] = acts[:, logical(phys_rows[j])].
        let timer = StageTimer::start();
        let perm = self.store.permutation(id0);
        g.xs.clear();
        g.xs.resize(t * bucket, 0.0);
        for (j, &p) in g.phys_rows.iter().enumerate() {
            let logical = perm.map(|pm| pm.old_of(p)).unwrap_or(p);
            for ti in 0..t {
                g.xs[ti * bucket + j] = acts[ti * in_rows + logical];
            }
        }
        stats.host += timer.finish();

        // Rows the prefetch buffer already holds need no fresh read; the
        // residual demand is planned as one cross-matrix batch. Coverage is
        // identical across members (the prefetcher requested the same
        // chunks for each), so the lead member's cursor decides.
        g.residual.clear();
        match prefetched {
            None => g.residual.extend_from_slice(&g.flash_chunks),
            Some(pre) => {
                let lead = MatrixId::new(layer, members[0]);
                let mut cursor = RowCursor::new(pre, lead);
                for chunk in &g.flash_chunks {
                    let mut run: Option<usize> = None;
                    for r in chunk.start..chunk.end() {
                        if cursor.advance_to(r).is_some() {
                            if let Some(s) = run.take() {
                                g.residual.push(Chunk::new(s, r - s));
                            }
                        } else if run.is_none() {
                            run = Some(r);
                        }
                    }
                    if let Some(s) = run {
                        g.residual.push(Chunk::new(s, chunk.end() - s));
                    }
                }
            }
        }

        // One planned submission covering every member's residual rows.
        let empty: &[Chunk] = &[];
        let mut requests: [(MatrixId, &[Chunk]); 3] = [(id0, empty); 3];
        for (i, member) in members.iter().enumerate() {
            requests[i] = (MatrixId::new(layer, *member), g.residual.as_slice());
        }
        self.planner.plan_refs_into(
            &self.store.layout,
            &requests[..members.len()],
            Some(&self.table),
            plan_scratch,
            &mut g.fresh.plan,
        );
        bucket
    }

    /// Stage 6 (gather half) — assemble per-member weight buckets: fresh
    /// read → prefetch buffer → hot-neuron cache, walking `phys_rows` in
    /// ascending order. The executor reads these buffers in place (no
    /// clones). Every row's bytes come from the shared flash image (or
    /// the engine-level cache), so a batch cohort sharing one compute
    /// set can reuse a single member's gathered tile bit-identically.
    pub(crate) fn gather_group_weights(
        &self,
        layer: usize,
        kind: MatrixKind,
        bucket: usize,
        prefetched: Option<&PlannedRead>,
        g: &mut GatherScratch,
        stats: &mut StageStats,
    ) {
        let members = group_members(kind);
        let have_fresh = !g.fresh.plan.is_empty();
        let dtype = self.store.dtype();
        let timer = StageTimer::start();
        for (mi, member) in members.iter().enumerate() {
            let id = MatrixId::new(layer, *member);
            let cols = self.spec.shape_of(*member).cols;
            let w = &mut g.weights[mi];
            w.clear();
            w.resize(bucket * cols, 0.0);
            let mut fresh_cursor = if have_fresh {
                Some(RowCursor::new(&g.fresh, id))
            } else {
                None
            };
            let mut pre_cursor = prefetched.map(|p| RowCursor::new(p, id));
            // Monotone cursor over the chunk-cache staged rows (ascending,
            // like `phys_rows`). It advances even when a fresh/prefetched
            // read serves the row — a staged row may also sit in the
            // prefetch buffer, and either source is bit-identical.
            let mut ci = 0usize;
            for (j, &p) in g.phys_rows.iter().enumerate() {
                while ci < g.cache_rows.len() && g.cache_rows[ci] < p {
                    ci += 1;
                }
                let dst = &mut w[j * cols..(j + 1) * cols];
                if let Some(bytes) = fresh_cursor.as_mut().and_then(|cur| cur.advance_to(p)) {
                    decode_row_into(dtype, bytes, dst);
                    continue;
                }
                if let Some(bytes) = pre_cursor.as_mut().and_then(|cur| cur.advance_to(p)) {
                    decode_row_into(dtype, bytes, dst);
                    stats.prefetch_hits += 1;
                    continue;
                }
                if ci < g.cache_rows.len() && g.cache_rows[ci] == p {
                    dst.copy_from_slice(&g.cache_data[mi][ci * cols..(ci + 1) * cols]);
                    continue;
                }
                if let Some(cache) = &self.neuron_cache {
                    if let Some(row) = cache.row_data(id, p) {
                        dst.copy_from_slice(row);
                    }
                }
            }
        }
        stats.host += timer.finish();
    }

    /// Stage 5 — execute one group's compiled stage artifact over the
    /// gathered weights for a single stream, then scatter the outputs
    /// into the forward buffers (and append K/V for the attention
    /// group). The batch driver replaces this with the multi-stream
    /// kernels for cohorts that share a weight tile.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn exec_group_solo(
        &self,
        group: usize,
        t: usize,
        bucket: usize,
        kv: &mut KvCache,
        g: &GatherScratch,
        fwd: &mut FwdBufs,
        exec: &mut ExecScratch,
        outs: &mut StageOutputs,
        stats: &mut StageStats,
    ) -> Result<()> {
        let d = self.meta.d;
        let h = self.meta.h;
        let c = self.spec.cache_slots;
        match group {
            0 => {
                let timer = StageTimer::start();
                let (kc, vc, kmask) = kv.views();
                let name = self.artifact_name("qkv", t, bucket)?;
                let inputs = [
                    TensorView::mat(t, bucket, &g.xs),
                    TensorView::mat(bucket, d, &g.weights[0]),
                    TensorView::mat(bucket, d, &g.weights[1]),
                    TensorView::mat(bucket, d, &g.weights[2]),
                    TensorView::mat(c, d, kc),
                    TensorView::mat(c, d, vc),
                    TensorView::vec1(c, kmask),
                ];
                self.runtime
                    .execute_into(name, &inputs, self.exec_threads, exec, outs)?;
                stats.compute += timer.finish();
                std::mem::swap(&mut fwd.attn, &mut outs.out[0]);
                kv.append(&outs.out[1], &outs.out[2]);
            }
            1 => {
                let timer = StageTimer::start();
                let name = self.artifact_name("projres", t, bucket)?;
                let inputs = [
                    TensorView::mat(t, bucket, &g.xs),
                    TensorView::mat(bucket, d, &g.weights[0]),
                    TensorView::mat(t, d, &fwd.xa),
                ];
                self.runtime
                    .execute_into(name, &inputs, self.exec_threads, exec, outs)?;
                stats.compute += timer.finish();
                std::mem::swap(&mut fwd.xb, &mut outs.out[0]);
            }
            2 => {
                let timer = StageTimer::start();
                let name = self.artifact_name("gateup", t, bucket)?;
                let inputs = [
                    TensorView::mat(t, bucket, &g.xs),
                    TensorView::mat(bucket, h, &g.weights[0]),
                    TensorView::mat(bucket, h, &g.weights[1]),
                ];
                self.runtime
                    .execute_into(name, &inputs, self.exec_threads, exec, outs)?;
                stats.compute += timer.finish();
                std::mem::swap(&mut fwd.act, &mut outs.out[0]);
            }
            _ => {
                let timer = StageTimer::start();
                let name = self.artifact_name("projres", t, bucket)?;
                let inputs = [
                    TensorView::mat(t, bucket, &g.xs),
                    TensorView::mat(bucket, d, &g.weights[0]),
                    TensorView::mat(t, d, &fwd.xb),
                ];
                self.runtime
                    .execute_into(name, &inputs, self.exec_threads, exec, outs)?;
                stats.compute += timer.finish();
                std::mem::swap(&mut fwd.xa, &mut outs.out[0]);
            }
        }
        Ok(())
    }
}

/// Scale-free RMSNorm over each of `t` rows of width `d` (host-side; the
/// coordinator needs the values for scoring anyway).
pub fn rmsnorm(x: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut out = Vec::new();
    rmsnorm_into(x, t, d, &mut out);
    out
}

/// Allocation-free [`rmsnorm`]: clears and refills `out`.
pub fn rmsnorm_into(x: &[f32], t: usize, d: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(t * d, 0.0);
    for ti in 0..t {
        let row = &x[ti * d..(ti + 1) * d];
        let ms: f64 = row.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d as f64;
        let inv = 1.0 / (ms + 1e-6).sqrt();
        for (o, &v) in out[ti * d..(ti + 1) * d].iter_mut().zip(row) {
            *o = (v as f64 * inv) as f32;
        }
    }
}

/// Mean |activation| per column over `t` tokens (§B.2's multi-token
/// importance).
pub fn col_importance(x: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut imp = Vec::new();
    col_importance_into(x, t, d, &mut imp);
    imp
}

/// Allocation-free [`col_importance`]: clears and refills `out`.
pub fn col_importance_into(x: &[f32], t: usize, d: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(d, 0.0);
    for ti in 0..t {
        for j in 0..d {
            out[j] += x[ti * d + j].abs();
        }
    }
    let inv = 1.0 / t as f32;
    out.iter_mut().for_each(|v| *v *= inv);
}

pub(crate) fn full_mask(n: usize) -> SelectionMask {
    SelectionMask::full(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit_rms() {
        let x: Vec<f32> = (0..128).map(|i| (i as f32 - 64.0) * 0.3).collect();
        let out = rmsnorm(&x, 2, 64);
        for ti in 0..2 {
            let ms: f64 = out[ti * 64..(ti + 1) * 64]
                .iter()
                .map(|&v| (v as f64).powi(2))
                .sum::<f64>()
                / 64.0;
            assert!((ms - 1.0).abs() < 1e-3, "rms {ms}");
        }
    }

    #[test]
    fn col_importance_means_abs() {
        let x = vec![1.0f32, -2.0, 3.0, -4.0]; // t=2, d=2
        let imp = col_importance(&x, 2, 2);
        assert_eq!(imp, vec![2.0, 3.0]);
    }
}
