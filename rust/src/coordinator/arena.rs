//! Per-session scratch arena: every buffer the serving hot path needs,
//! owned once and reused every call.
//!
//! The engine's steady-state forward pass (`append_frame` / `decode_step`)
//! draws **all** of its working memory from here — activation ping-pong
//! buffers, gather staging, selection candidates and radix-sort scratch,
//! plan command/segment vectors, device receipts, and executor
//! temporaries. Buffers grow to their high-water mark during the first
//! (warm-up) call and never reallocate afterwards, which is what the
//! allocation-regression integration test pins down: zero heap
//! allocations per `decode_step` after warm-up.

use crate::latency::Chunk;
use crate::plan::{PlanScratch, PlannedRead};
use crate::runtime::{ExecScratch, StageOutputs};
use crate::sparsify::{SelectScratch, SelectionMask};
use crate::storage::PoolScratch;

/// Activation buffers of the layer loop. `xa` holds the running hidden
/// state (layer input, overwritten by the down-projection residual
/// output), `xb` the post-attention residual (`x1`); neither is ever an
/// input and output of the same stage execution.
#[derive(Debug, Default)]
pub(crate) struct FwdBufs {
    pub xa: Vec<f32>,
    pub xb: Vec<f32>,
    /// RMS-normed stage input (reused for both norm sites of a layer).
    pub hn: Vec<f32>,
    /// Attention output.
    pub attn: Vec<f32>,
    /// SwiGLU activation output.
    pub act: Vec<f32>,
    /// Per-column importance of the current stage input.
    pub imp: Vec<f32>,
}

/// Gather/staging buffers of one selection-group load.
#[derive(Debug, Default)]
pub(crate) struct GatherScratch {
    /// Gathered + zero-padded activations `[t, bucket]`.
    pub xs: Vec<f32>,
    /// Per-member weight buckets `[bucket, cols]` (Q-led groups use all
    /// three slots, others fewer).
    pub weights: [Vec<f32>; 3],
    /// Union of selected + cached physical rows, ascending.
    pub phys_rows: Vec<usize>,
    /// Row membership bitmap (hot-neuron-cache union only).
    pub selset: Vec<bool>,
    /// Flash chunk demand recorded for next-call prefetch.
    pub flash_chunks: Vec<Chunk>,
    /// Residual demand after prefetch-buffer subtraction.
    pub residual: Vec<Chunk>,
    /// The stage's fresh planned read (plan + receipt, pooled).
    pub fresh: PlannedRead,
    /// Rows the shared chunk cache serves this stage (ascending; the
    /// gather cursor walks it in lockstep with `phys_rows`).
    pub cache_rows: Vec<usize>,
    /// The cached weights for `cache_rows`, per member, row-major.
    pub cache_data: [Vec<f32>; 3],
    /// Run-splitting scratch for the cache's chunk subtraction.
    pub cache_tmp: Vec<Chunk>,
}

/// The complete per-session scratch arena.
#[derive(Debug, Default)]
pub(crate) struct ScratchArena {
    /// The current layer's prefetched whole-layer read, swapped out of the
    /// session's prefetch slot at layer start.
    pub pre: PlannedRead,
    pub fwd: FwdBufs,
    pub gather: GatherScratch,
    /// Selection output mask (reused across stages).
    pub sel: SelectionMask,
    pub sel_scratch: SelectScratch,
    /// Importance moved into physical (reordered) row space.
    pub imp_phys: Vec<f32>,
    pub plan_scratch: PlanScratch,
    /// Sharded-plan working memory + per-member staging receipts for the
    /// storage pool, plus per-call per-member I/O accounting.
    pub pool: PoolScratch,
    pub exec: ExecScratch,
    pub outs: StageOutputs,
}

impl ScratchArena {
    /// Pre-reserve worst-case capacity for every buffer whose length
    /// depends on the *shape* of a selection (chunk counts drift token to
    /// token as activations evolve, so warm-up alone cannot bound them).
    /// Deterministic-size buffers (norms, importance, executor scratch)
    /// reach their fixed high-water marks on the warm-up call regardless.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn reserve(
        &mut self,
        n_max: usize,
        t_max: usize,
        max_chunks: usize,
        xs_cap: usize,
        w_cap: usize,
        group_bytes: usize,
        layer_bytes: usize,
    ) {
        self.sel.mask.reserve(n_max);
        self.sel.chunks.reserve(max_chunks);
        self.imp_phys.reserve(n_max);
        self.gather.phys_rows.reserve(n_max);
        self.gather.selset.reserve(n_max);
        self.gather.flash_chunks.reserve(max_chunks);
        self.gather.residual.reserve(max_chunks);
        self.gather.xs.reserve(xs_cap);
        for w in &mut self.gather.weights {
            w.reserve(w_cap);
        }
        self.gather.cache_rows.reserve(n_max);
        self.gather.cache_tmp.reserve(max_chunks);
        for v in &mut self.gather.cache_data {
            v.reserve(w_cap);
        }
        // One selection group: at most 3 members × one span per chunk; a
        // whole prefetched layer: all 7 matrices.
        self.plan_scratch.reserve(7 * max_chunks);
        self.gather.fresh.reserve(group_bytes, 3 * max_chunks, 3 * max_chunks);
        self.pre.reserve(layer_bytes, 7 * max_chunks, 7 * max_chunks);
        let act_cap = t_max * n_max;
        self.fwd.xa.reserve(act_cap);
        self.fwd.xb.reserve(act_cap);
        self.fwd.hn.reserve(act_cap);
        self.fwd.attn.reserve(act_cap);
        self.fwd.act.reserve(act_cap);
        self.fwd.imp.reserve(n_max);
        for o in &mut self.outs.out {
            o.reserve(act_cap);
        }
    }
}
