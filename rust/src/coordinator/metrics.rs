//! Per-stage metrics: the latency-breakdown accounting behind Fig 8.
//!
//! I/O time is *virtual* when the flash device is simulated (the device
//! returns modeled service time) and wall-clock against real files;
//! compute/select/gather times are always wall-clock. The engine sums
//! them into an end-to-end latency the same way the paper's breakdown
//! does.
//!
//! Batched-serving keys: `io.shared_bytes` counts bytes the fused
//! cross-stream plans read **once** instead of once per subscriber (the
//! dedup ratio is `shared / (shared + io bytes)`), and
//! `batch.occupancy` records one count per fused batch with the member
//! total in its byte counter — `bytes / count` is the average achieved
//! batch occupancy.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulated counters per named stage.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    totals: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
    bytes: BTreeMap<String, u64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, stage: &str, d: Duration) {
        // Probe-then-insert: the stage key is only allocated the first
        // time it is seen, keeping steady-state serving allocation-free.
        match self.totals.get_mut(stage) {
            Some(t) => *t += d,
            None => {
                self.totals.insert(stage.to_string(), d);
            }
        }
        match self.counts.get_mut(stage) {
            Some(c) => *c += 1,
            None => {
                self.counts.insert(stage.to_string(), 1);
            }
        }
    }

    pub fn add_bytes(&mut self, stage: &str, n: u64) {
        match self.bytes.get_mut(stage) {
            Some(b) => *b += n,
            None => {
                self.bytes.insert(stage.to_string(), n);
            }
        }
    }

    pub fn total(&self, stage: &str) -> Duration {
        self.totals.get(stage).copied().unwrap_or_default()
    }

    pub fn count(&self, stage: &str) -> u64 {
        self.counts.get(stage).copied().unwrap_or_default()
    }

    pub fn bytes(&self, stage: &str) -> u64 {
        self.bytes.get(stage).copied().unwrap_or_default()
    }

    pub fn stages(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.totals.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// All `(stage, count)` pairs (the `/metrics` exposition walks these;
    /// count keys are a subset of the duration keys).
    pub fn counts_iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counts.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// All `(stage, bytes)` pairs — byte counters are keyed independently
    /// of durations (e.g. `batch.occupancy` has bytes but no duration).
    pub fn bytes_iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.bytes.iter().map(|(k, v)| (k.as_str(), *v))
    }

    /// Merge another metrics block into this one.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += *v;
        }
        for (k, v) in &other.bytes {
            *self.bytes.entry(k.clone()).or_default() += *v;
        }
    }

    pub fn reset(&mut self) {
        self.totals.clear();
        self.counts.clear();
        self.bytes.clear();
    }

    /// Sum of all stage durations (end-to-end latency proxy).
    pub fn grand_total(&self) -> Duration {
        self.totals.values().sum()
    }
}

/// RAII-less explicit stage timer (wall clock).
pub struct StageTimer {
    start: Instant,
}

impl StageTimer {
    pub fn start() -> Self {
        Self {
            start: Instant::now(),
        }
    }

    pub fn stop(self, metrics: &mut Metrics, stage: &str) -> Duration {
        let d = self.start.elapsed();
        metrics.add(stage, d);
        d
    }

    /// Elapsed time without metrics accounting — for callers that batch
    /// their fold into shared metrics (one lock per request instead of
    /// one per stage).
    pub fn finish(self) -> Duration {
        self.start.elapsed()
    }
}

impl Default for StageTimer {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = Metrics::new();
        m.add("io", Duration::from_millis(5));
        m.add("io", Duration::from_millis(7));
        m.add("compute", Duration::from_millis(3));
        assert_eq!(m.total("io"), Duration::from_millis(12));
        assert_eq!(m.count("io"), 2);
        assert_eq!(m.grand_total(), Duration::from_millis(15));
        assert_eq!(m.total("nope"), Duration::ZERO);
    }

    #[test]
    fn merge_combines() {
        let mut a = Metrics::new();
        a.add("x", Duration::from_secs(1));
        a.add_bytes("x", 100);
        let mut b = Metrics::new();
        b.add("x", Duration::from_secs(2));
        b.add("y", Duration::from_secs(3));
        b.add_bytes("x", 50);
        a.merge(&b);
        assert_eq!(a.total("x"), Duration::from_secs(3));
        assert_eq!(a.total("y"), Duration::from_secs(3));
        assert_eq!(a.bytes("x"), 150);
    }

    #[test]
    fn timer_measures() {
        let mut m = Metrics::new();
        let t = StageTimer::start();
        std::thread::sleep(Duration::from_millis(2));
        let d = t.stop(&mut m, "sleep");
        assert!(d >= Duration::from_millis(2));
        assert_eq!(m.count("sleep"), 1);
    }
}
