//! Per-stream KV cache: fixed-capacity ring over C slots, exported as the
//! flat `[C, d]` tensors + validity mask the XLA artifacts expect.
//! Attention is permutation-invariant over slots, so ring overwrites need
//! no compaction.

use crate::runtime::Tensor;

#[derive(Clone, Debug)]
pub struct KvCache {
    capacity: usize,
    dim: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    mask: Vec<f32>,
    cursor: usize,
    filled: usize,
    /// Total tokens ever appended (including overwritten).
    appended: u64,
}

impl KvCache {
    pub fn new(capacity: usize, dim: usize) -> Self {
        Self {
            capacity,
            dim,
            k: vec![0.0; capacity * dim],
            v: vec![0.0; capacity * dim],
            mask: vec![0.0; capacity],
            cursor: 0,
            filled: 0,
            appended: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.filled
    }

    pub fn is_empty(&self) -> bool {
        self.filled == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn appended(&self) -> u64 {
        self.appended
    }

    /// Append `t` tokens of K/V (row-major [t, dim]); overwrites oldest
    /// slots when full.
    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), v.len());
        assert_eq!(k.len() % self.dim, 0);
        let t = k.len() / self.dim;
        for i in 0..t {
            let slot = self.cursor;
            self.k[slot * self.dim..(slot + 1) * self.dim]
                .copy_from_slice(&k[i * self.dim..(i + 1) * self.dim]);
            self.v[slot * self.dim..(slot + 1) * self.dim]
                .copy_from_slice(&v[i * self.dim..(i + 1) * self.dim]);
            self.mask[slot] = 1.0;
            self.cursor = (self.cursor + 1) % self.capacity;
            self.filled = (self.filled + 1).min(self.capacity);
            self.appended += 1;
        }
    }

    /// Borrow the (k, v, mask) planes without copying — the serving path
    /// hands these to the executor as [`crate::runtime::TensorView`]s.
    pub fn views(&self) -> (&[f32], &[f32], &[f32]) {
        (&self.k, &self.v, &self.mask)
    }

    /// Export as (k, v, mask) tensors for the XLA artifacts (allocating;
    /// calibration/test convenience — serving uses [`KvCache::views`]).
    pub fn tensors(&self) -> (Tensor, Tensor, Tensor) {
        (
            Tensor::new(vec![self.capacity, self.dim], self.k.clone()),
            Tensor::new(vec![self.capacity, self.dim], self.v.clone()),
            Tensor::new(vec![self.capacity], self.mask.clone()),
        )
    }

    /// Capture a rollback point covering up to `tokens` future appends,
    /// reusing `mark`'s buffers (allocation-free at steady state). A
    /// ring append *overwrites* old slots, so position alone cannot be
    /// restored — the mark saves the contents of the slots the next
    /// `tokens` appends will claim. Used by the transactional decode
    /// batch: on a mid-batch failure every member's KV is rolled back
    /// so a failed call never leaves state partially advanced.
    pub fn mark_into(&self, tokens: usize, mark: &mut KvMark) {
        let n = tokens.min(self.capacity);
        mark.cursor = self.cursor;
        mark.filled = self.filled;
        mark.appended = self.appended;
        mark.slots = n;
        mark.k.clear();
        mark.v.clear();
        mark.mask.clear();
        for i in 0..n {
            let s = (self.cursor + i) % self.capacity;
            mark.k.extend_from_slice(&self.k[s * self.dim..(s + 1) * self.dim]);
            mark.v.extend_from_slice(&self.v[s * self.dim..(s + 1) * self.dim]);
            mark.mask.push(self.mask[s]);
        }
    }

    /// Undo every append made since `mark` was captured: restore the
    /// overwritten slots, then the ring head. Panics if more appends
    /// happened than the mark's window covers (callers size the window
    /// to the batch's token count).
    pub fn rollback(&mut self, mark: &KvMark) {
        let n = (self.appended - mark.appended) as usize;
        assert!(
            n <= mark.slots,
            "rollback window exceeded: {n} appends for {} saved slots",
            mark.slots
        );
        for i in 0..n {
            let s = (mark.cursor + i) % self.capacity;
            self.k[s * self.dim..(s + 1) * self.dim]
                .copy_from_slice(&mark.k[i * self.dim..(i + 1) * self.dim]);
            self.v[s * self.dim..(s + 1) * self.dim]
                .copy_from_slice(&mark.v[i * self.dim..(i + 1) * self.dim]);
            self.mask[s] = mark.mask[i];
        }
        self.cursor = mark.cursor;
        self.filled = mark.filled;
        self.appended = mark.appended;
    }

    pub fn clear(&mut self) {
        self.k.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.mask.iter_mut().for_each(|x| *x = 0.0);
        self.cursor = 0;
        self.filled = 0;
        self.appended = 0;
    }
}

/// Rollback point of one [`KvCache`] (see [`KvCache::mark_into`]).
/// Reusable: buffers keep their capacity across marks.
#[derive(Clone, Debug, Default)]
pub struct KvMark {
    cursor: usize,
    filled: usize,
    appended: u64,
    slots: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    mask: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mark_rollback_restores_overwritten_slots() {
        let mut kv = KvCache::new(2, 1);
        kv.append(&[1.0], &[10.0]);
        kv.append(&[2.0], &[20.0]); // full: next append overwrites slot 0
        let mut mark = KvMark::default();
        kv.mark_into(1, &mut mark);
        kv.append(&[3.0], &[30.0]); // destroys slot 0's (1.0, 10.0)
        assert_eq!(kv.tensors().0.data, vec![3.0, 2.0]);
        kv.rollback(&mark);
        assert_eq!(kv.len(), 2);
        assert_eq!(kv.appended(), 2);
        let (k, v, m) = kv.tensors();
        assert_eq!(k.data, vec![1.0, 2.0]);
        assert_eq!(v.data, vec![10.0, 20.0]);
        assert_eq!(m.data, vec![1.0, 1.0]);
        // Re-appending after rollback behaves as if the failed append
        // never happened.
        kv.append(&[4.0], &[40.0]);
        assert_eq!(kv.tensors().0.data, vec![4.0, 2.0]);
    }

    #[test]
    fn rollback_with_no_appends_is_noop() {
        let mut kv = KvCache::new(4, 2);
        kv.append(&[1.0, 2.0], &[3.0, 4.0]);
        let mut mark = KvMark::default();
        kv.mark_into(1, &mut mark);
        let before = kv.tensors();
        kv.rollback(&mark);
        let after = kv.tensors();
        assert_eq!(before.0.data, after.0.data);
        assert_eq!(kv.len(), 1);
    }

    #[test]
    fn rollback_restores_mask_of_fresh_slots() {
        let mut kv = KvCache::new(3, 1);
        kv.append(&[1.0], &[10.0]);
        let mut mark = KvMark::default();
        kv.mark_into(1, &mut mark);
        kv.append(&[2.0], &[20.0]); // fresh slot, mask 0 -> 1
        kv.rollback(&mark);
        assert_eq!(kv.len(), 1);
        assert_eq!(kv.tensors().2.data, vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn append_and_mask() {
        let mut kv = KvCache::new(4, 2);
        assert!(kv.is_empty());
        kv.append(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(kv.len(), 2);
        let (k, _v, m) = kv.tensors();
        assert_eq!(&k.data[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.data, vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut kv = KvCache::new(2, 1);
        kv.append(&[1.0], &[10.0]);
        kv.append(&[2.0], &[20.0]);
        kv.append(&[3.0], &[30.0]); // overwrites slot 0
        assert_eq!(kv.len(), 2);
        assert_eq!(kv.appended(), 3);
        let (k, v, m) = kv.tensors();
        assert_eq!(k.data, vec![3.0, 2.0]);
        assert_eq!(v.data, vec![30.0, 20.0]);
        assert_eq!(m.data, vec![1.0, 1.0]);
    }

    #[test]
    fn multi_token_append_wraps() {
        let mut kv = KvCache::new(3, 1);
        kv.append(&[1.0, 2.0, 3.0, 4.0], &[0.0; 4]);
        let (k, _, m) = kv.tensors();
        // 4 appends into 3 slots: slot0 overwritten by token 3 (value 4).
        assert_eq!(k.data, vec![4.0, 2.0, 3.0]);
        assert_eq!(m.data, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn clear_resets() {
        let mut kv = KvCache::new(2, 2);
        kv.append(&[1.0, 1.0], &[1.0, 1.0]);
        kv.clear();
        assert!(kv.is_empty());
        assert_eq!(kv.tensors().2.data, vec![0.0, 0.0]);
    }
}
