//! Request scheduler: multi-stream frame-append/decode traffic over one
//! engine, served by a configurable worker pool.
//!
//! Decode steps are latency-critical (a user is waiting on tokens) and
//! preempt queued frame appends — the standard serving-priority split.
//! The engine core is `Sync`, so all workers share one [`Engine`] handle;
//! each stream index lazily gets its own [`Session`], and callers talk
//! through channels. With `workers > 1`, independent streams decode
//! genuinely in parallel over the same flash device and weight store,
//! while a per-stream in-flight guard keeps each stream's requests in
//! submission order (within each priority class) no matter which worker
//! picks them up.
//!
//! ## Cross-stream decode batching
//!
//! With a non-zero [`SchedulerConfig::batch_window`], a worker that picks
//! up a decode request keeps collecting further *ready* decode requests —
//! oldest first, at most one per stream (the in-flight guard enforces
//! this for free), up to [`SchedulerConfig::max_batch`] — waiting up to
//! the window for more to arrive, then serves the whole group as **one
//! fused batch** ([`Engine::decode_batch_into`]): per-stream selection,
//! shared chunks read from flash once, shared weight tiles executed
//! across all member activations. Every member still gets its own
//! [`Completion`], and outputs are bit-identical to solo decoding, so
//! batching only trades a bounded queueing delay (≤ the window) for
//! I/O dedup and kernel-dispatch amortization. Appends are never
//! batched and still yield to decodes; a batch whose validation fails
//! falls back to solo decodes so one bad stream cannot poison the
//! others.

use std::collections::{HashSet, VecDeque};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{DecodeRequest, Engine, Session, StageStats, MAX_DECODE_BATCH};

/// What a request asks the engine to do.
#[derive(Clone, Debug)]
pub enum RequestKind {
    /// Append a frame of token embeddings ([T, d] row-major).
    AppendFrame(Vec<f32>),
    /// Decode one token from its embedding ([d]).
    Decode(Vec<f32>),
}

impl RequestKind {
    pub fn name(&self) -> &'static str {
        match self {
            RequestKind::AppendFrame(_) => "append",
            RequestKind::Decode(_) => "decode",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub stream: usize,
    pub kind: RequestKind,
}

/// Completed request: output hidden states + accounting.
#[derive(Clone, Debug)]
pub struct Completion {
    pub stream: usize,
    pub kind: &'static str,
    pub output: Result<Vec<f32>, String>,
    pub stats: StageStats,
    /// Time spent queued before execution started.
    pub queue_wait: Duration,
    /// Execution wall time (includes virtual-I/O accounting only in
    /// `stats`, not here).
    pub exec_wall: Duration,
}

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Maximum queued requests before `submit` returns an error
    /// (backpressure).
    pub max_queue: usize,
    /// Maximum distinct stream indices (sessions are created lazily up to
    /// this bound; requests beyond it are rejected at submit).
    pub max_streams: usize,
    /// Worker threads draining the queues. 1 preserves strict serial
    /// execution; more lets independent streams run concurrently over the
    /// shared engine core.
    pub workers: usize,
    /// Cross-stream decode-batching window: a worker that picked up a
    /// decode waits up to this long for further ready decodes from other
    /// streams before serving the group as one fused batch.
    /// `Duration::ZERO` (the default) disables batching entirely.
    pub batch_window: Duration,
    /// Most decode requests fused into one batch (clamped to
    /// [`MAX_DECODE_BATCH`]; values ≤ 1 disable batching).
    pub max_batch: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        // NC_SCHED_WORKERS / NC_BATCH_WINDOW_US let CI (and operators)
        // exercise the concurrent and batched paths without touching
        // call sites.
        let workers = std::env::var("NC_SCHED_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(1);
        let batch_window = std::env::var("NC_BATCH_WINDOW_US")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_micros)
            .unwrap_or(Duration::ZERO);
        Self {
            max_queue: 256,
            max_streams: 64,
            workers,
            batch_window,
            max_batch: 4,
        }
    }
}

struct Job {
    request: Request,
    enqueued: Instant,
    done: Sender<Completion>,
}

#[derive(Default)]
struct Queues {
    decode: VecDeque<Job>,
    append: VecDeque<Job>,
    /// Streams with a request currently executing on some worker. A
    /// stream's queued requests wait for its in-flight one, so
    /// per-stream submission order is preserved even with many workers
    /// (the session mutex alone would serialize but not order).
    busy: HashSet<usize>,
    stopping: bool,
}

impl Queues {
    fn len(&self) -> usize {
        self.decode.len() + self.append.len()
    }
}

/// Pop the oldest job whose stream is not currently in flight, keeping
/// the relative order of everything left behind.
fn pop_ready(queue: &mut VecDeque<Job>, busy: &HashSet<usize>) -> Option<Job> {
    let idx = queue
        .iter()
        .position(|j| !busy.contains(&j.request.stream))?;
    queue.remove(idx)
}

struct Shared {
    queues: Mutex<Queues>,
    cv: Condvar,
    /// Lazily-created per-stream sessions, shared by all workers.
    sessions: Mutex<Vec<Option<Arc<Session>>>>,
}

/// Decode-batching knobs handed to each worker.
#[derive(Clone, Copy)]
struct BatchCfg {
    window: Duration,
    max_batch: usize,
}

impl BatchCfg {
    fn enabled(&self) -> bool {
        self.window > Duration::ZERO && self.max_batch > 1
    }
}

/// Thread-pool-backed scheduler around an [`Engine`].
pub struct Scheduler {
    shared: Arc<Shared>,
    cfg: SchedulerConfig,
    /// Drained exactly once: [`Scheduler::shutdown`] is idempotent (the
    /// network server's signal path and `Drop` may both call it).
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    worker_count: usize,
    engine: Engine,
}

impl Scheduler {
    /// Build the engine (on the calling thread) and spawn the worker
    /// pool; every worker shares the engine through cheap handle clones.
    pub fn spawn<F>(cfg: SchedulerConfig, make_engine: F) -> Self
    where
        F: FnOnce() -> Engine + Send + 'static,
    {
        let shared = Arc::new(Shared {
            queues: Mutex::new(Queues::default()),
            cv: Condvar::new(),
            sessions: Mutex::new(Vec::new()),
        });
        let engine = make_engine();
        let batch = BatchCfg {
            window: cfg.batch_window,
            max_batch: cfg.max_batch.min(MAX_DECODE_BATCH),
        };
        let worker_count = cfg.workers.max(1);
        let workers = (0..worker_count)
            .map(|_| {
                let shared = shared.clone();
                let engine = engine.clone();
                std::thread::spawn(move || worker_loop(shared, engine, batch))
            })
            .collect();
        Self {
            shared,
            cfg,
            workers: Mutex::new(workers),
            worker_count,
            engine,
        }
    }

    /// A handle to the scheduler's engine (metrics inspection, warmup,
    /// calibration — the core is shared with the workers).
    pub fn engine(&self) -> Engine {
        self.engine.clone()
    }

    /// Enqueue a request; returns the completion receiver, or an error if
    /// the queue is full (backpressure), the stream index is out of
    /// bounds, or the scheduler is stopping.
    pub fn submit(&self, request: Request) -> anyhow::Result<Receiver<Completion>> {
        anyhow::ensure!(
            request.stream < self.cfg.max_streams,
            "stream {} beyond max_streams {}",
            request.stream,
            self.cfg.max_streams
        );
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut q = self.shared.queues.lock().unwrap();
            anyhow::ensure!(!q.stopping, "scheduler is stopping");
            anyhow::ensure!(
                q.len() < self.cfg.max_queue,
                "queue full ({} requests)",
                self.cfg.max_queue
            );
            let job = Job {
                request,
                enqueued: Instant::now(),
                done: tx,
            };
            match &job.request.kind {
                RequestKind::Decode(_) => q.decode.push_back(job),
                RequestKind::AppendFrame(_) => q.append.push_back(job),
            }
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    pub fn queued(&self) -> usize {
        self.shared.queues.lock().unwrap().len()
    }

    /// Number of worker threads serving the queues.
    pub fn workers(&self) -> usize {
        self.worker_count
    }

    /// Configured stream-index bound (requests at or beyond it are
    /// rejected at submit).
    pub fn max_streams(&self) -> usize {
        self.cfg.max_streams
    }

    /// Drain queued work and stop the workers. Idempotent: a second call
    /// (or the implicit one from `Drop`) finds the worker pool already
    /// drained and returns immediately — the network server's shutdown
    /// path and `Drop` may both get here without panicking or
    /// deadlocking.
    pub fn shutdown(&self) {
        self.stop_inner();
        let mut workers = self.workers.lock().unwrap();
        for w in workers.drain(..) {
            let _ = w.join();
        }
    }

    fn stop_inner(&self) {
        self.shared.queues.lock().unwrap().stopping = true;
        self.shared.cv.notify_all();
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Fetch (or lazily create) the session of one stream.
fn stream_session(shared: &Arc<Shared>, engine: &Engine, stream: usize) -> Arc<Session> {
    let mut slots = shared.sessions.lock().unwrap();
    if slots.len() <= stream {
        slots.resize_with(stream + 1, || None);
    }
    slots[stream]
        .get_or_insert_with(|| Arc::new(engine.new_session()))
        .clone()
}

fn worker_loop(shared: Arc<Shared>, engine: Engine, batch: BatchCfg) {
    let mut jobs: Vec<Job> = Vec::new();
    loop {
        jobs.clear();
        {
            let mut guard = shared.queues.lock().unwrap();
            loop {
                // Priority: decode before append; streams with an
                // in-flight request are skipped so per-stream order holds.
                let q = &mut *guard;
                if let Some(j) = pop_ready(&mut q.decode, &q.busy) {
                    q.busy.insert(j.request.stream);
                    jobs.push(j);
                    break;
                }
                if let Some(j) = pop_ready(&mut q.append, &q.busy) {
                    q.busy.insert(j.request.stream);
                    jobs.push(j);
                    break;
                }
                if q.stopping {
                    break;
                }
                guard = shared.cv.wait(guard).unwrap();
            }
            // Cross-stream decode batching: keep collecting ready
            // decodes (oldest first — the busy guard already enforces at
            // most one per stream) up to `max_batch`, waiting out the
            // bounded window for more to arrive. Appends never batch.
            let decode_lead = jobs
                .first()
                .is_some_and(|j| matches!(j.request.kind, RequestKind::Decode(_)));
            if batch.enabled() && decode_lead {
                let deadline = Instant::now() + batch.window;
                loop {
                    {
                        let q = &mut *guard;
                        while jobs.len() < batch.max_batch {
                            match pop_ready(&mut q.decode, &q.busy) {
                                Some(j) => {
                                    q.busy.insert(j.request.stream);
                                    jobs.push(j);
                                }
                                None => break,
                            }
                        }
                    }
                    if jobs.len() >= batch.max_batch || guard.stopping {
                        break;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    guard = shared.cv.wait_timeout(guard, deadline - now).unwrap().0;
                }
            }
        }
        if jobs.is_empty() {
            return; // stopping, nothing left to serve
        }
        if jobs.len() == 1 {
            let job = jobs.pop().expect("one job claimed");
            run_single(&shared, &engine, job);
        } else {
            run_decode_batch(&shared, &engine, &mut jobs);
        }
        // Online cache adaptation rides the serving loop: every Nth
        // completed job one worker runs a maintenance pass (admissions
        // from live selection frequency, drift check, possible
        // re-reorder). One relaxed atomic when the cache is off.
        engine.cache_tick();
    }
}

/// Serve one request on its stream's session and deliver the completion.
fn run_single(shared: &Arc<Shared>, engine: &Engine, job: Job) {
    let queue_wait = job.enqueued.elapsed();
    let session = stream_session(shared, engine, job.request.stream);
    let t0 = Instant::now();
    let (output, stats) = match &job.request.kind {
        RequestKind::AppendFrame(f) => match session.append_frame(f) {
            Ok((y, s)) => (Ok(y), s),
            Err(e) => (Err(e.to_string()), StageStats::default()),
        },
        RequestKind::Decode(tok) => match session.decode_step(tok) {
            Ok((y, s)) => (Ok(y), s),
            Err(e) => (Err(e.to_string()), StageStats::default()),
        },
    };
    let stream = job.request.stream;
    let _ = job.done.send(Completion {
        stream,
        kind: job.request.kind.name(),
        output,
        stats,
        queue_wait,
        exec_wall: t0.elapsed(),
    });
    // Release the stream; any worker may now serve its next queued
    // request (notify_all: the waiter isn't necessarily the one the
    // submit-side notify_one woke).
    shared.queues.lock().unwrap().busy.remove(&stream);
    shared.cv.notify_all();
}

/// Serve a group of decode jobs (distinct streams) as one fused batch;
/// every member gets its own completion.
///
/// Members that would fail the batch's all-or-nothing validation for a
/// *predictable* reason (no primed KV yet) are screened out up front and
/// served solo, so they get their own error while the rest still batch.
/// If the fused batch itself errors, the members are retried **solo**:
/// a failed [`Engine::decode_batch_into`] rolls every member's KV back
/// to its pre-batch state (transactional), so re-decoding the same
/// token solo is safe and bit-identical to having never batched. The
/// stream that actually carries the fault (e.g. its selection needs an
/// extent only a dead member holds) gets its own error completion while
/// the innocent members still complete.
fn run_decode_batch(shared: &Arc<Shared>, engine: &Engine, jobs: &mut Vec<Job>) {
    let streams: Vec<usize> = jobs.iter().map(|j| j.request.stream).collect();
    let sessions: Vec<Arc<Session>> = jobs
        .iter()
        .map(|j| stream_session(shared, engine, j.request.stream))
        .collect();
    let waits: Vec<Duration> = jobs.iter().map(|j| j.enqueued.elapsed()).collect();

    // Screen out members that cannot decode yet; serve them solo for
    // their own per-stream error (or result, if a frame landed
    // in-between). `ready` keeps (job index) of the batchable rest.
    let mut ready: Vec<usize> = Vec::with_capacity(jobs.len());
    let mut solo_done: Vec<(usize, Result<Vec<f32>, String>, StageStats, Duration)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        if sessions[i].kv_tokens() > 0 {
            ready.push(i);
            continue;
        }
        let RequestKind::Decode(tok) = &job.request.kind else {
            unreachable!("batches hold decode requests only");
        };
        let t0 = Instant::now();
        let (output, st) = match sessions[i].decode_step(tok) {
            Ok((y, s)) => (Ok(y), s),
            Err(e) => (Err(e.to_string()), StageStats::default()),
        };
        solo_done.push((i, output, st, t0.elapsed()));
    }

    let mut outs = vec![Vec::new(); ready.len()];
    let mut stats = vec![StageStats::default(); ready.len()];
    let t0 = Instant::now();
    let batch_result = if ready.is_empty() {
        Ok(())
    } else {
        let reqs: Vec<DecodeRequest> = ready
            .iter()
            .map(|&i| {
                let RequestKind::Decode(tok) = &jobs[i].request.kind else {
                    unreachable!("batches hold decode requests only");
                };
                DecodeRequest {
                    session: &sessions[i],
                    token: tok,
                }
            })
            .collect();
        engine.decode_batch_into(&reqs, &mut outs, &mut stats)
    };
    let exec_wall = t0.elapsed();

    // Deliver the batch members' completions. A failed batch rolled
    // every member's KV back, so each member is retried solo: innocent
    // streams complete normally and only the faulty one carries the
    // error.
    for (bi, &i) in ready.iter().enumerate() {
        let (output, st, wall) = match &batch_result {
            Ok(()) => (Ok(std::mem::take(&mut outs[bi])), stats[bi], exec_wall),
            Err(_) => {
                let RequestKind::Decode(tok) = &jobs[i].request.kind else {
                    unreachable!("batches hold decode requests only");
                };
                let solo_t0 = Instant::now();
                match sessions[i].decode_step(tok) {
                    Ok((y, s)) => (Ok(y), s, exec_wall + solo_t0.elapsed()),
                    Err(e) => (
                        Err(e.to_string()),
                        StageStats::default(),
                        exec_wall + solo_t0.elapsed(),
                    ),
                }
            }
        };
        let job = &jobs[i];
        let _ = job.done.send(Completion {
            stream: job.request.stream,
            kind: "decode",
            output,
            stats: st,
            queue_wait: waits[i],
            exec_wall: wall,
        });
    }
    // And the screened-out members' solo completions.
    for (i, output, st, wall) in solo_done {
        let job = &jobs[i];
        let _ = job.done.send(Completion {
            stream: job.request.stream,
            kind: "decode",
            output,
            stats: st,
            queue_wait: waits[i],
            exec_wall: wall,
        });
    }
    jobs.clear();

    // Release every member stream at once.
    {
        let mut q = shared.queues.lock().unwrap();
        for s in &streams {
            q.busy.remove(s);
        }
    }
    shared.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Single-worker config regardless of NC_SCHED_WORKERS: these tests
    /// assert strict serial-execution properties.
    fn serial_cfg() -> SchedulerConfig {
        SchedulerConfig {
            workers: 1,
            ..SchedulerConfig::default()
        }
    }

    fn spawn_tiny_cfg(cfg: SchedulerConfig) -> Scheduler {
        Scheduler::spawn(cfg, move || {
            Engine::builder("tiny")
                .policy(Policy::TopK)
                .sparsity(0.3)
                .artifacts(&artifact_dir())
                .build()
                .unwrap()
        })
    }

    fn spawn_tiny() -> Scheduler {
        spawn_tiny_cfg(SchedulerConfig::default())
    }

    fn tiny_frame() -> Vec<f32> {
        crate::workload::FrameTrace::new(64, 8, 4, 3).frame(0)
    }

    #[test]
    fn processes_append_and_decode() {
        let s = spawn_tiny();
        let rx = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::AppendFrame(tiny_frame()),
            })
            .unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.kind, "append");
        let y = c.output.unwrap();
        assert_eq!(y.len(), 8 * 64);
        let rx = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::Decode(vec![0.1; 64]),
            })
            .unwrap();
        let c = rx.recv().unwrap();
        assert!(c.output.is_ok());
        assert!(c.stats.io > Duration::ZERO);
        s.shutdown();
    }

    #[test]
    fn decode_preempts_queued_appends() {
        let s = spawn_tiny_cfg(serial_cfg());
        // Prime stream 0 so decode is legal (decode preempts *everything*,
        // including a not-yet-started priming append, so wait for it).
        let first = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::AppendFrame(tiny_frame()),
            })
            .unwrap();
        first.recv().unwrap().output.unwrap();
        // Queue: several appends on stream 1, then a decode on stream 0.
        // The worker may already be chewing on the first queued append,
        // but the decode must jump ahead of the later ones.
        let append_rxs: Vec<_> = (0..3)
            .map(|_| {
                s.submit(Request {
                    stream: 1,
                    kind: RequestKind::AppendFrame(tiny_frame()),
                })
                .unwrap()
            })
            .collect();
        let decode_rx = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::Decode(vec![0.05; 64]),
            })
            .unwrap();
        let d = decode_rx.recv().unwrap();
        d.output.clone().unwrap();
        // The decode must have waited less than the last queued append.
        let last_append = append_rxs.last().unwrap().recv().unwrap();
        assert!(
            d.queue_wait <= last_append.queue_wait,
            "decode waited {:?}, append {:?}",
            d.queue_wait,
            last_append.queue_wait
        );
        s.shutdown();
    }

    #[test]
    fn backpressure() {
        let s = Scheduler::spawn(
            SchedulerConfig {
                max_queue: 2,
                workers: 1,
                ..SchedulerConfig::default()
            },
            || {
                Engine::builder("tiny")
                    .artifacts(&artifact_dir())
                    .build()
                    .unwrap()
            },
        );
        // Saturate: the worker takes the first, queue holds two more.
        let mut rxs = Vec::new();
        let mut rejected = false;
        for _ in 0..8 {
            match s.submit(Request {
                stream: 0,
                kind: RequestKind::AppendFrame(tiny_frame()),
            }) {
                Ok(rx) => rxs.push(rx),
                Err(_) => {
                    rejected = true;
                    break;
                }
            }
        }
        assert!(rejected, "queue should overflow");
        for rx in rxs {
            rx.recv().unwrap().output.unwrap();
        }
        s.shutdown();
    }

    #[test]
    fn errors_surface_in_completion() {
        let s = spawn_tiny();
        // Decode without prior append is an engine error, not a crash.
        let rx = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::Decode(vec![0.0; 64]),
            })
            .unwrap();
        let c = rx.recv().unwrap();
        assert!(c.output.is_err());
        s.shutdown();
    }

    #[test]
    fn out_of_bounds_stream_rejected() {
        let s = Scheduler::spawn(
            SchedulerConfig {
                max_streams: 2,
                ..SchedulerConfig::default()
            },
            || {
                Engine::builder("tiny")
                    .artifacts(&artifact_dir())
                    .build()
                    .unwrap()
            },
        );
        assert!(s
            .submit(Request {
                stream: 2,
                kind: RequestKind::AppendFrame(tiny_frame()),
            })
            .is_err());
        s.shutdown();
    }

    #[test]
    fn same_stream_requests_stay_ordered_across_workers() {
        // Pipelined appends on ONE stream with a 4-worker pool: the
        // per-stream in-flight guard must keep them in submission order
        // (KV state makes every output order-sensitive).
        let s = spawn_tiny_cfg(SchedulerConfig {
            workers: 4,
            ..SchedulerConfig::default()
        });
        let trace = crate::workload::FrameTrace::new(64, 8, 8, 3);
        let rxs: Vec<_> = (0..4)
            .map(|f| {
                s.submit(Request {
                    stream: 0,
                    kind: RequestKind::AppendFrame(trace.frame(f)),
                })
                .unwrap()
            })
            .collect();
        let outs: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().output.unwrap())
            .collect();
        s.shutdown();
        let reference = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        let session = reference.new_session();
        for (f, out) in outs.iter().enumerate() {
            let (want, _) = session.append_frame(&trace.frame(f)).unwrap();
            assert_eq!(out, &want, "frame {f} executed out of order");
        }
    }

    #[test]
    fn shutdown_with_queued_requests_drains_cleanly() {
        // Satellite regression: shutdown while requests are still queued
        // must not deadlock any worker, and every submitted request must
        // either complete or be cleanly rejected (its channel
        // disconnects) — never hang.
        let s = spawn_tiny_cfg(serial_cfg());
        let rxs: Vec<_> = (0..6)
            .map(|i| {
                s.submit(Request {
                    stream: i % 3,
                    kind: RequestKind::AppendFrame(tiny_frame()),
                })
                .unwrap()
            })
            .collect();
        // Shut down immediately: the single worker is at most one job
        // in; the rest are still queued.
        s.shutdown();
        let mut completed = 0usize;
        let mut rejected = 0usize;
        for rx in rxs {
            // After shutdown() joined the workers, every sender side is
            // either used or dropped, so recv() cannot block.
            match rx.recv() {
                Ok(c) => {
                    c.output.unwrap();
                    completed += 1;
                }
                Err(_) => rejected += 1,
            }
        }
        assert_eq!(completed + rejected, 6);
        // The drain semantics deliver everything that was queued before
        // the stop flag was observed.
        assert!(completed >= 1, "at least the in-flight job completes");
    }

    #[test]
    fn shutdown_is_idempotent() {
        // Satellite regression: the network server's signal path and
        // `Drop` may both call shutdown — the second call (and the
        // implicit Drop after both) must neither panic nor deadlock,
        // and submits after shutdown must be clean errors.
        let s = spawn_tiny_cfg(serial_cfg());
        let rx = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::AppendFrame(tiny_frame()),
            })
            .unwrap();
        rx.recv().unwrap().output.unwrap();
        s.shutdown();
        s.shutdown();
        assert!(
            s.submit(Request {
                stream: 0,
                kind: RequestKind::AppendFrame(tiny_frame()),
            })
            .is_err(),
            "submit after shutdown must be rejected"
        );
        drop(s); // third stop via Drop — still clean
    }

    #[test]
    fn batched_decodes_match_solo_reference() {
        // One worker + a batching window: four decode requests from four
        // primed streams coalesce into fused batches, and every stream's
        // output must be bit-identical to a solo single-session
        // reference.
        let s = spawn_tiny_cfg(SchedulerConfig {
            workers: 1,
            batch_window: Duration::from_millis(500),
            max_batch: 4,
            ..SchedulerConfig::default()
        });
        let trace = crate::workload::FrameTrace::new(64, 8, 8, 3);
        // Prime each stream with its own frame.
        let rxs: Vec<_> = (0..4)
            .map(|stream| {
                s.submit(Request {
                    stream,
                    kind: RequestKind::AppendFrame(trace.frame(stream)),
                })
                .unwrap()
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().output.unwrap();
        }
        // Two decode rounds; submissions land fast enough to batch.
        let token = vec![0.04f32; 64];
        let mut rounds: Vec<Vec<Vec<f32>>> = Vec::new();
        for _ in 0..2 {
            let rxs: Vec<_> = (0..4)
                .map(|stream| {
                    s.submit(Request {
                        stream,
                        kind: RequestKind::Decode(token.clone()),
                    })
                    .unwrap()
                })
                .collect();
            rounds.push(
                rxs.into_iter()
                    .map(|rx| rx.recv().unwrap().output.unwrap())
                    .collect(),
            );
        }
        // Batches actually formed (occupancy metric counts members).
        let m = s.engine().metrics();
        assert!(
            m.bytes("batch.occupancy") >= 2,
            "expected at least one fused batch, got occupancy bytes {}",
            m.bytes("batch.occupancy")
        );
        s.shutdown();
        // Reference: identical engine, solo sessions per stream — the
        // batched outputs must be bit-identical.
        let reference = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        for stream in 0..4usize {
            let session = reference.new_session();
            session.append_frame(&trace.frame(stream)).unwrap();
            for (round, outs) in rounds.iter().enumerate() {
                let (want, _) = session.decode_step(&token).unwrap();
                assert_eq!(
                    outs[stream], want,
                    "stream {stream} diverged under batching at round {round}"
                );
            }
        }
    }

    #[test]
    fn batched_fallback_isolates_invalid_streams() {
        // Stream 1 decodes without a primed KV: the batch falls back to
        // solo decodes, stream 1 gets its error, stream 0 still
        // completes.
        let s = spawn_tiny_cfg(SchedulerConfig {
            workers: 1,
            batch_window: Duration::from_millis(300),
            max_batch: 4,
            ..SchedulerConfig::default()
        });
        let prime = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::AppendFrame(tiny_frame()),
            })
            .unwrap();
        prime.recv().unwrap().output.unwrap();
        let good = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::Decode(vec![0.02; 64]),
            })
            .unwrap();
        let bad = s
            .submit(Request {
                stream: 1,
                kind: RequestKind::Decode(vec![0.02; 64]),
            })
            .unwrap();
        assert!(good.recv().unwrap().output.is_ok());
        assert!(bad.recv().unwrap().output.is_err());
        s.shutdown();
    }

    #[test]
    fn fused_batch_device_error_isolates_faulty_stream() {
        // A persistent injected device error during a fused batch must
        // produce exactly one error completion: the fused attempt burns
        // READ_ATTEMPTS reads, rolls every member back (transactional
        // decode_batch), and the scheduler retries each stream solo —
        // the first solo retry burns the remaining READ_ATTEMPTS and
        // errors, the rest see a healthy device and complete with
        // outputs bit-identical to a fault-free reference.
        use crate::storage::{FaultConfig, READ_ATTEMPTS};
        let build = || {
            Engine::builder("tiny")
                .policy(Policy::TopK)
                .sparsity(0.3)
                .devices(1)
                .exec_threads(1)
                .prefetch(false)
                .async_io(false)
                .artifacts(&artifact_dir())
                .build()
                .unwrap()
        };
        let engine = build();
        let fault = engine.inject_faults(0, FaultConfig::default());
        let s = Scheduler::spawn(
            SchedulerConfig {
                workers: 1,
                batch_window: Duration::from_millis(300),
                max_batch: 4,
                ..SchedulerConfig::default()
            },
            move || engine,
        );
        let trace = crate::workload::FrameTrace::new(64, 8, 4, 3);
        for stream in 0..3usize {
            s.submit(Request {
                stream,
                kind: RequestKind::AppendFrame(trace.frame(stream)),
            })
            .unwrap()
            .recv()
            .unwrap()
            .output
            .unwrap();
        }
        let token = vec![0.02f32; 64];
        let rxs: Vec<_> = (0..3)
            .map(|stream| {
                s.submit(Request {
                    stream,
                    kind: RequestKind::Decode(token.clone()),
                })
                .unwrap()
            })
            .collect();
        // Armed inside the batch window (the worker is still collecting
        // arrivals), so the whole budget lands on the fused execution.
        fault.fail_next(2 * READ_ATTEMPTS as u64);
        let outs: Vec<Result<Vec<f32>, String>> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().output).collect();
        s.shutdown();
        let errs: Vec<bool> = outs.iter().map(Result::is_err).collect();
        assert_eq!(
            errs.iter().filter(|&&e| e).count(),
            1,
            "exactly one stream absorbs the persistent fault: {errs:?}"
        );
        let reference = build();
        for (stream, out) in outs.iter().enumerate() {
            if let Ok(y) = out {
                let session = reference.new_session();
                session.append_frame(&trace.frame(stream)).unwrap();
                let (want, _) = session.decode_step(&token).unwrap();
                assert_eq!(y, &want, "stream {stream} diverged after batch fault recovery");
            }
        }
    }

    #[test]
    fn worker_pool_serves_streams_concurrently_and_correctly() {
        // 4 workers, 4 streams: per-stream outputs must match a serial
        // single-session reference exactly (stream isolation under
        // concurrency), and every request must complete.
        let cfg = SchedulerConfig {
            workers: 4,
            ..SchedulerConfig::default()
        };
        let s = spawn_tiny_cfg(cfg);
        assert_eq!(s.workers(), 4);
        let frames: Vec<Vec<f32>> = (0..4)
            .map(|i| crate::workload::FrameTrace::new(64, 8, 8, 3).frame(i))
            .collect();
        let rxs: Vec<_> = (0..4)
            .map(|stream| {
                s.submit(Request {
                    stream,
                    kind: RequestKind::AppendFrame(frames[stream].clone()),
                })
                .unwrap()
            })
            .collect();
        let outs: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().output.unwrap())
            .collect();
        // Decodes on every stream, concurrently.
        let drxs: Vec<_> = (0..4)
            .map(|stream| {
                s.submit(Request {
                    stream,
                    kind: RequestKind::Decode(vec![0.02; 64]),
                })
                .unwrap()
            })
            .collect();
        for rx in drxs {
            rx.recv().unwrap().output.unwrap();
        }
        s.shutdown();
        // Reference: an identically-built engine, one serial session per
        // stream (deterministic weights per seed ⇒ identical outputs).
        let reference = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        for (stream, out) in outs.iter().enumerate() {
            let session = reference.new_session();
            let (want, _) = session.append_frame(&frames[stream]).unwrap();
            assert_eq!(out, &want, "stream {stream} diverged under concurrency");
        }
    }
}
