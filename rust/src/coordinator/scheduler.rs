//! Request scheduler: multi-stream prefill/decode traffic over one
//! engine, served by a configurable worker pool with SLO-aware
//! admission control.
//!
//! ## Disaggregated prefill/decode queues
//!
//! Vision prefills (frame appends) are long and bandwidth-bound; decode
//! steps are short and latency-bound (a user is waiting on tokens). The
//! scheduler keeps them in **separate queues by scheduling class** —
//! `interactive` (decode by default) and `bulk` (prefill by default) —
//! and serves the interactive queue first, earliest-deadline-first
//! within it. A request may override its class
//! ([`RequestOpts::class`]), so a latency-critical prefill can ride the
//! interactive queue and a background decode can yield to others.
//!
//! The engine core is `Sync`, so all workers share one [`Engine`]
//! handle; each stream index lazily gets its own [`Session`], and
//! callers talk through channels. A per-stream in-flight guard keeps
//! each stream's requests in submission order no matter which worker
//! picks them up (the EDF pop never lifts a job over an earlier queued
//! job of the same stream).
//!
//! ## Chunked prefill
//!
//! With a non-zero [`SchedulerConfig::prefill_chunk`], a worker serving
//! a prefill runs it through the resumable pass driver
//! ([`Session::prefill_begin`] / [`Session::prefill_step`]) a few
//! layers at a time, and **interleaves ready decode work at every
//! yield point** — one decode batch (or solo decode) per yield, so
//! both classes make bounded progress. Chunked prefill outputs are
//! bit-identical to the monolithic path (pausing between layers
//! changes no computation; the determinism suite pins it), so the knob
//! trades nothing but scheduling latency shape. `prefill_chunk = 0`
//! restores the monolithic single-queue behaviour — the measurable
//! baseline for the `mixed_slo` bench sweep.
//!
//! ## Admission control
//!
//! With a configured [`SchedulerConfig::slo`], `submit` sheds new work
//! of a class (typed [`SubmitError::Overloaded`], HTTP 429 upstream)
//! once that class's queue delay — the age of its oldest queued
//! request — exceeds the SLO, with a `retry_after` hint sized to the
//! excess. Per-stream prefill admission is additionally bounded by
//! [`SchedulerConfig::prefill_budget`] outstanding tokens
//! ([`SubmitError::BudgetExhausted`]); the hard queue cap stays a 503
//! ([`SubmitError::QueueFull`]). Per-class served/shed counts and
//! cumulative queue delay are exported via [`Scheduler::admission`]
//! for `/metrics`.
//!
//! ## Cross-stream decode batching
//!
//! With a non-zero [`SchedulerConfig::batch_window`], a worker that
//! picks up a decode request keeps collecting further *ready* decode
//! requests — earliest deadline first, at most one per stream (the
//! in-flight guard enforces this for free), up to
//! [`SchedulerConfig::max_batch`] — waiting up to the window for more
//! to arrive, then serves the whole group as **one fused batch**
//! ([`Engine::decode_batch_into`]): per-stream selection, shared chunks
//! read from flash once, shared weight tiles executed across all member
//! activations. Every member still gets its own [`Completion`], and
//! outputs are bit-identical to solo decoding. Prefills are never
//! batched; a batch whose validation fails falls back to solo decodes
//! so one bad stream cannot poison the others.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{DecodeRequest, Engine, Session, StageStats, MAX_DECODE_BATCH};

/// Scheduling class of a request: which queue it joins and which SLO
/// accounting bucket it lands in.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Class {
    /// Latency-bound: served first, earliest deadline first. The
    /// default for decode steps.
    Interactive,
    /// Bandwidth-bound: fills worker capacity the interactive queue
    /// leaves idle. The default for prefills.
    Bulk,
}

impl Class {
    pub fn as_str(&self) -> &'static str {
        match self {
            Class::Interactive => "interactive",
            Class::Bulk => "bulk",
        }
    }
}

impl std::str::FromStr for Class {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "interactive" => Ok(Class::Interactive),
            "bulk" => Ok(Class::Bulk),
            other => Err(format!(
                "unknown class {other:?} (expected \"interactive\" or \"bulk\")"
            )),
        }
    }
}

/// Per-request scheduling options, carried end to end from the HTTP
/// body to the queues.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RequestOpts {
    /// Scheduling-class override; `None` uses the per-operation default
    /// (decode → interactive, prefill → bulk).
    pub class: Option<Class>,
    /// Queue-delay deadline relative to submission; orders the
    /// interactive queue (earliest first). `None` uses the configured
    /// SLO (or a fixed default when no SLO is set), so undeadlined
    /// requests keep FIFO order among themselves.
    pub deadline: Option<Duration>,
}

/// What a request asks the engine to do: the typed request API carried
/// through scheduler, server, and load harness.
#[derive(Clone, Debug)]
pub enum Request {
    /// Append a frame of token embeddings ([T, d] row-major).
    Prefill {
        stream: usize,
        frame: Vec<f32>,
        opts: RequestOpts,
    },
    /// Decode one token from its embedding ([d]).
    Decode {
        stream: usize,
        token: Vec<f32>,
        opts: RequestOpts,
    },
}

impl Request {
    /// A prefill with default options (bulk class, SLO-default deadline).
    pub fn prefill(stream: usize, frame: Vec<f32>) -> Self {
        Request::Prefill {
            stream,
            frame,
            opts: RequestOpts::default(),
        }
    }

    /// A decode with default options (interactive class, SLO-default
    /// deadline).
    pub fn decode(stream: usize, token: Vec<f32>) -> Self {
        Request::Decode {
            stream,
            token,
            opts: RequestOpts::default(),
        }
    }

    /// Replace the scheduling options (builder style).
    pub fn with_opts(mut self, new: RequestOpts) -> Self {
        match &mut self {
            Request::Prefill { opts, .. } | Request::Decode { opts, .. } => *opts = new,
        }
        self
    }

    pub fn stream(&self) -> usize {
        match self {
            Request::Prefill { stream, .. } | Request::Decode { stream, .. } => *stream,
        }
    }

    pub fn opts(&self) -> &RequestOpts {
        match self {
            Request::Prefill { opts, .. } | Request::Decode { opts, .. } => opts,
        }
    }

    /// Effective scheduling class: the explicit override, else the
    /// per-operation default.
    pub fn class(&self) -> Class {
        self.opts().class.unwrap_or(match self {
            Request::Prefill { .. } => Class::Bulk,
            Request::Decode { .. } => Class::Interactive,
        })
    }

    pub fn is_decode(&self) -> bool {
        matches!(self, Request::Decode { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Request::Prefill { .. } => "prefill",
            Request::Decode { .. } => "decode",
        }
    }
}

/// Completed request: output hidden states + accounting.
#[derive(Clone, Debug)]
pub struct Completion {
    pub stream: usize,
    pub kind: &'static str,
    pub output: Result<Vec<f32>, String>,
    pub stats: StageStats,
    /// Time spent queued before execution started.
    pub queue_wait: Duration,
    /// Execution wall time (includes virtual-I/O accounting only in
    /// `stats`, not here).
    pub exec_wall: Duration,
}

/// Why `submit` refused a request. `Overloaded` and `BudgetExhausted`
/// are *sheds* — transient, retry after `retry_after` (HTTP 429
/// upstream); `QueueFull` and `Stopping` map to 503, `UnknownStream`
/// to a client error.
#[derive(Clone, Debug)]
pub enum SubmitError {
    /// The class's queue delay exceeds the configured SLO.
    Overloaded {
        class: Class,
        queue_delay: Duration,
        retry_after: Duration,
    },
    /// The stream already has `prefill_budget` prefill tokens queued.
    BudgetExhausted {
        stream: usize,
        queued_tokens: usize,
        budget: usize,
        retry_after: Duration,
    },
    /// Hard queue-capacity backpressure.
    QueueFull { queued: usize, retry_after: Duration },
    /// Stream index at or beyond `max_streams`.
    UnknownStream { stream: usize, max_streams: usize },
    /// The scheduler is shutting down.
    Stopping,
}

impl SubmitError {
    /// Suggested client back-off, where one applies.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            SubmitError::Overloaded { retry_after, .. }
            | SubmitError::BudgetExhausted { retry_after, .. }
            | SubmitError::QueueFull { retry_after, .. } => Some(*retry_after),
            SubmitError::UnknownStream { .. } | SubmitError::Stopping => None,
        }
    }

    /// True for SLO/budget sheds (HTTP 429); false for capacity or
    /// lifecycle refusals (503) and caller errors.
    pub fn is_shed(&self) -> bool {
        matches!(
            self,
            SubmitError::Overloaded { .. } | SubmitError::BudgetExhausted { .. }
        )
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded {
                class,
                queue_delay,
                retry_after,
            } => write!(
                f,
                "{} queue delay {:?} past SLO; retry in {:?}",
                class.as_str(),
                queue_delay,
                retry_after
            ),
            SubmitError::BudgetExhausted {
                stream,
                queued_tokens,
                budget,
                retry_after,
            } => write!(
                f,
                "stream {stream} has {queued_tokens} of {budget} prefill tokens queued; retry in {retry_after:?}"
            ),
            SubmitError::QueueFull {
                queued,
                retry_after,
            } => write!(f, "queue full ({queued} requests); retry in {retry_after:?}"),
            SubmitError::UnknownStream {
                stream,
                max_streams,
            } => write!(f, "stream {stream} beyond max_streams {max_streams}"),
            SubmitError::Stopping => write!(f, "scheduler is stopping"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Deadline assumed for requests that don't carry one when no SLO is
/// configured either (keeps the interactive queue totally ordered).
const DEFAULT_DEADLINE: Duration = Duration::from_millis(100);

/// Floor for `retry_after` hints.
const MIN_RETRY_AFTER: Duration = Duration::from_millis(1);

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Maximum queued requests before `submit` returns
    /// [`SubmitError::QueueFull`] (hard backpressure).
    pub max_queue: usize,
    /// Maximum distinct stream indices (sessions are created lazily up to
    /// this bound; requests beyond it are rejected at submit).
    pub max_streams: usize,
    /// Worker threads draining the queues. 1 preserves strict serial
    /// execution; more lets independent streams run concurrently over the
    /// shared engine core.
    pub workers: usize,
    /// Cross-stream decode-batching window: a worker that picked up a
    /// decode waits up to this long for further ready decodes from other
    /// streams before serving the group as one fused batch.
    /// `Duration::ZERO` (the default) disables batching entirely.
    pub batch_window: Duration,
    /// Most decode requests fused into one batch (clamped to
    /// [`MAX_DECODE_BATCH`]; values ≤ 1 disable batching).
    pub max_batch: usize,
    /// Queue-delay SLO: once a class's oldest queued request is older
    /// than this, further submits of that class shed with
    /// [`SubmitError::Overloaded`]. `None` (the default) disables
    /// shedding — only the hard queue cap pushes back.
    pub slo: Option<Duration>,
    /// Maximum outstanding (queued or executing) prefill *tokens* per
    /// stream; beyond it prefill submits shed with
    /// [`SubmitError::BudgetExhausted`]. 0 (the default) = unlimited.
    pub prefill_budget: usize,
    /// Chunked prefill: yield to the interactive queue every this many
    /// layers. 0 = monolithic prefill (the single-queue baseline).
    pub prefill_chunk: usize,
}

impl SchedulerConfig {
    /// The environment-derived configuration. `NC_SCHED_WORKERS`,
    /// `NC_BATCH_WINDOW_US`, `NC_SLO_MS`, `NC_PREFILL_BUDGET` and
    /// `NC_PREFILL_CHUNK` let CI (and operators) exercise the
    /// concurrent, batched, and disaggregated paths without touching
    /// call sites. This is the single place those variables are parsed;
    /// `Default` delegates here.
    pub fn from_env() -> Self {
        fn env_usize(name: &str) -> Option<usize> {
            std::env::var(name).ok().and_then(|v| v.parse().ok())
        }
        let workers = env_usize("NC_SCHED_WORKERS").filter(|&n| n >= 1).unwrap_or(1);
        let batch_window = env_usize("NC_BATCH_WINDOW_US")
            .map(|us| Duration::from_micros(us as u64))
            .unwrap_or(Duration::ZERO);
        let slo = env_usize("NC_SLO_MS")
            .filter(|&ms| ms > 0)
            .map(|ms| Duration::from_millis(ms as u64));
        let prefill_budget = env_usize("NC_PREFILL_BUDGET").unwrap_or(0);
        let prefill_chunk = env_usize("NC_PREFILL_CHUNK").unwrap_or(1);
        Self {
            max_queue: 256,
            max_streams: 64,
            workers,
            batch_window,
            max_batch: 4,
            slo,
            prefill_budget,
            prefill_chunk,
        }
    }

    pub fn with_max_queue(mut self, max_queue: usize) -> Self {
        self.max_queue = max_queue;
        self
    }

    pub fn with_max_streams(mut self, max_streams: usize) -> Self {
        self.max_streams = max_streams;
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_batch_window(mut self, window: Duration) -> Self {
        self.batch_window = window;
        self
    }

    pub fn with_max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    pub fn with_slo(mut self, slo: Option<Duration>) -> Self {
        self.slo = slo;
        self
    }

    pub fn with_prefill_budget(mut self, tokens: usize) -> Self {
        self.prefill_budget = tokens;
        self
    }

    pub fn with_prefill_chunk(mut self, layers: usize) -> Self {
        self.prefill_chunk = layers;
        self
    }
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self::from_env()
    }
}

struct Job {
    request: Request,
    class: Class,
    /// Absolute deadline ordering the interactive queue (EDF).
    deadline_at: Instant,
    /// Prefill tokens this job holds against its stream's budget
    /// (0 when untracked: decodes, or no budget configured).
    tokens: usize,
    enqueued: Instant,
    done: Sender<Completion>,
}

impl Job {
    fn stream(&self) -> usize {
        self.request.stream()
    }
}

#[derive(Default)]
struct Queues {
    /// Latency-bound class, earliest-deadline-first.
    interactive: VecDeque<Job>,
    /// Bandwidth-bound class, FIFO.
    bulk: VecDeque<Job>,
    /// Streams with a request currently executing on some worker. A
    /// stream's queued requests wait for its in-flight one, so
    /// per-stream submission order is preserved even with many workers
    /// (the session mutex alone would serialize but not order).
    busy: HashSet<usize>,
    /// Outstanding prefill tokens per stream (tracked only when a
    /// budget is configured; entries are removed at zero).
    prefill_tokens: HashMap<usize, usize>,
    stopping: bool,
}

impl Queues {
    fn len(&self) -> usize {
        self.interactive.len() + self.bulk.len()
    }

    fn queue(&self, class: Class) -> &VecDeque<Job> {
        match class {
            Class::Interactive => &self.interactive,
            Class::Bulk => &self.bulk,
        }
    }

    /// The class's current queue delay: age of its oldest queued
    /// request (both queues are pushed at the back and removed from
    /// anywhere, so the front is always the oldest).
    fn queue_delay(&self, class: Class, now: Instant) -> Duration {
        self.queue(class)
            .front()
            .map(|j| now.saturating_duration_since(j.enqueued))
            .unwrap_or(Duration::ZERO)
    }

    fn release_tokens(&mut self, stream: usize, tokens: usize) {
        if tokens == 0 {
            return;
        }
        if let Some(held) = self.prefill_tokens.get_mut(&stream) {
            *held = held.saturating_sub(tokens);
            if *held == 0 {
                self.prefill_tokens.remove(&stream);
            }
        }
    }
}

/// Pop the oldest job whose stream is not currently in flight, keeping
/// the relative order of everything left behind (bulk/FIFO pop).
fn pop_ready(queue: &mut VecDeque<Job>, busy: &HashSet<usize>) -> Option<Job> {
    let idx = queue.iter().position(|j| !busy.contains(&j.stream()))?;
    queue.remove(idx)
}

/// EDF pop for the interactive queue: among ready jobs that are the
/// *first queued job of their stream* (lifting a later one would
/// reorder a stream's KV-order-sensitive requests), pick the earliest
/// deadline, oldest first on ties. `decode_only` restricts to decode
/// operations (batch collection and mid-prefill interleaving).
fn pop_ready_edf(
    queue: &mut VecDeque<Job>,
    busy: &HashSet<usize>,
    decode_only: bool,
) -> Option<Job> {
    let mut best: Option<(usize, Instant)> = None;
    for (i, job) in queue.iter().enumerate() {
        let stream = job.stream();
        if busy.contains(&stream) {
            continue;
        }
        // Head-of-stream check within this queue: an earlier queued job
        // of the same stream must run first.
        if queue.iter().take(i).any(|p| p.stream() == stream) {
            continue;
        }
        if decode_only && !job.request.is_decode() {
            continue;
        }
        match best {
            Some((_, d)) if job.deadline_at >= d => {}
            _ => best = Some((i, job.deadline_at)),
        }
    }
    queue.remove(best?.0)
}

/// Per-class admission/served accounting (relaxed atomics: the counters
/// feed `/metrics`, not control flow).
#[derive(Default)]
struct ClassCounters {
    served: AtomicU64,
    shed: AtomicU64,
    queue_delay_us: AtomicU64,
}

#[derive(Default)]
struct Admission {
    interactive: ClassCounters,
    bulk: ClassCounters,
}

impl Admission {
    fn class(&self, class: Class) -> &ClassCounters {
        match class {
            Class::Interactive => &self.interactive,
            Class::Bulk => &self.bulk,
        }
    }

    fn record_served(&self, class: Class, queue_wait: Duration) {
        let c = self.class(class);
        c.served.fetch_add(1, Ordering::Relaxed);
        c.queue_delay_us
            .fetch_add(queue_wait.as_micros() as u64, Ordering::Relaxed);
    }

    fn record_shed(&self, class: Class) {
        self.class(class).shed.fetch_add(1, Ordering::Relaxed);
    }
}

/// Point-in-time view of one class's admission accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClassSnapshot {
    /// Requests currently queued (not yet executing).
    pub queued: usize,
    /// Requests whose execution has started (cumulative).
    pub served: u64,
    /// Requests shed at admission (cumulative; SLO + budget sheds).
    pub shed: u64,
    /// Summed queue delay of served requests, µs (divide by `served`
    /// for the mean).
    pub queue_delay_us: u64,
}

/// Per-class admission snapshot ([`Scheduler::admission`]), the source
/// for the server's per-class `/metrics`.
#[derive(Clone, Copy, Debug, Default)]
pub struct AdmissionSnapshot {
    pub interactive: ClassSnapshot,
    pub bulk: ClassSnapshot,
}

impl AdmissionSnapshot {
    /// (class-name, snapshot) pairs, for metric emission loops.
    pub fn classes(&self) -> [(&'static str, ClassSnapshot); 2] {
        [
            (Class::Interactive.as_str(), self.interactive),
            (Class::Bulk.as_str(), self.bulk),
        ]
    }
}

struct Shared {
    queues: Mutex<Queues>,
    cv: Condvar,
    /// Lazily-created per-stream sessions, shared by all workers.
    sessions: Mutex<Vec<Option<Arc<Session>>>>,
    admission: Admission,
}

/// Scheduling knobs handed to each worker.
#[derive(Clone, Copy)]
struct WorkerCfg {
    window: Duration,
    max_batch: usize,
    /// Layers per chunked-prefill step; 0 = monolithic.
    prefill_chunk: usize,
}

impl WorkerCfg {
    fn batching(&self) -> bool {
        self.window > Duration::ZERO && self.max_batch > 1
    }
}

/// Thread-pool-backed scheduler around an [`Engine`].
pub struct Scheduler {
    shared: Arc<Shared>,
    cfg: SchedulerConfig,
    /// Tokens one prefill admits against the per-stream budget
    /// (the model's tokens-per-frame).
    frame_tokens: usize,
    /// Drained exactly once: [`Scheduler::shutdown`] is idempotent (the
    /// network server's signal path and `Drop` may both call it).
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    worker_count: usize,
    engine: Engine,
}

impl Scheduler {
    /// Build the engine (on the calling thread) and spawn the worker
    /// pool; every worker shares the engine through cheap handle clones.
    pub fn spawn<F>(cfg: SchedulerConfig, make_engine: F) -> Self
    where
        F: FnOnce() -> Engine + Send + 'static,
    {
        let shared = Arc::new(Shared {
            queues: Mutex::new(Queues::default()),
            cv: Condvar::new(),
            sessions: Mutex::new(Vec::new()),
            admission: Admission::default(),
        });
        let engine = make_engine();
        let frame_tokens = engine.meta().t;
        let wcfg = WorkerCfg {
            window: cfg.batch_window,
            max_batch: cfg.max_batch.min(MAX_DECODE_BATCH),
            prefill_chunk: cfg.prefill_chunk,
        };
        let worker_count = cfg.workers.max(1);
        let workers = (0..worker_count)
            .map(|_| {
                let shared = shared.clone();
                let engine = engine.clone();
                std::thread::spawn(move || worker_loop(shared, engine, wcfg))
            })
            .collect();
        Self {
            shared,
            cfg,
            frame_tokens,
            workers: Mutex::new(workers),
            worker_count,
            engine,
        }
    }

    /// A handle to the scheduler's engine (metrics inspection, warmup,
    /// calibration — the core is shared with the workers).
    pub fn engine(&self) -> Engine {
        self.engine.clone()
    }

    /// Enqueue a request; returns the completion receiver, or a typed
    /// [`SubmitError`]: SLO/budget sheds (retryable, 429 upstream),
    /// hard queue backpressure (503), bad stream index, or shutdown.
    pub fn submit(&self, request: Request) -> Result<Receiver<Completion>, SubmitError> {
        let stream = request.stream();
        if stream >= self.cfg.max_streams {
            return Err(SubmitError::UnknownStream {
                stream,
                max_streams: self.cfg.max_streams,
            });
        }
        let class = request.class();
        // Tokens held against the per-stream prefill budget (tracked
        // only when a budget is configured).
        let tokens = match (&request, self.cfg.prefill_budget) {
            (Request::Prefill { .. }, budget) if budget > 0 => self.frame_tokens.max(1),
            _ => 0,
        };
        let now = Instant::now();
        let default_deadline = self.cfg.slo.unwrap_or(DEFAULT_DEADLINE);
        let deadline_at = now + request.opts().deadline.unwrap_or(default_deadline);
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut q = self.shared.queues.lock().unwrap();
            if q.stopping {
                return Err(SubmitError::Stopping);
            }
            if q.len() >= self.cfg.max_queue {
                return Err(SubmitError::QueueFull {
                    queued: q.len(),
                    retry_after: default_deadline.max(MIN_RETRY_AFTER),
                });
            }
            // SLO admission: shed the class whose oldest queued request
            // has already waited past the SLO — adding to that queue
            // can only miss.
            if let Some(slo) = self.cfg.slo {
                let queue_delay = q.queue_delay(class, now);
                if queue_delay > slo {
                    self.shared.admission.record_shed(class);
                    let excess = queue_delay - slo;
                    return Err(SubmitError::Overloaded {
                        class,
                        queue_delay,
                        retry_after: excess.max(slo / 4).max(MIN_RETRY_AFTER),
                    });
                }
            }
            if tokens > 0 {
                let held = q.prefill_tokens.get(&stream).copied().unwrap_or(0);
                if held + tokens > self.cfg.prefill_budget {
                    self.shared.admission.record_shed(class);
                    return Err(SubmitError::BudgetExhausted {
                        stream,
                        queued_tokens: held,
                        budget: self.cfg.prefill_budget,
                        retry_after: self
                            .cfg
                            .slo
                            .unwrap_or(DEFAULT_DEADLINE)
                            .max(MIN_RETRY_AFTER),
                    });
                }
                *q.prefill_tokens.entry(stream).or_insert(0) += tokens;
            }
            let job = Job {
                request,
                class,
                deadline_at,
                tokens,
                enqueued: now,
                done: tx,
            };
            match class {
                Class::Interactive => q.interactive.push_back(job),
                Class::Bulk => q.bulk.push_back(job),
            }
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    pub fn queued(&self) -> usize {
        self.shared.queues.lock().unwrap().len()
    }

    /// Number of worker threads serving the queues.
    pub fn workers(&self) -> usize {
        self.worker_count
    }

    /// Configured stream-index bound (requests at or beyond it are
    /// rejected at submit).
    pub fn max_streams(&self) -> usize {
        self.cfg.max_streams
    }

    /// The full configuration this scheduler runs (for config surfacing
    /// — `/v1/config` reports the SLO and disaggregation knobs from
    /// here so the served values cannot drift from the scheduler's).
    pub fn config(&self) -> &SchedulerConfig {
        &self.cfg
    }

    /// Per-class admission snapshot: queue depths plus cumulative
    /// served/shed counts and queue delay.
    pub fn admission(&self) -> AdmissionSnapshot {
        let (iq, bq) = {
            let q = self.shared.queues.lock().unwrap();
            (q.interactive.len(), q.bulk.len())
        };
        let read = |c: &ClassCounters, queued: usize| ClassSnapshot {
            queued,
            served: c.served.load(Ordering::Relaxed),
            shed: c.shed.load(Ordering::Relaxed),
            queue_delay_us: c.queue_delay_us.load(Ordering::Relaxed),
        };
        AdmissionSnapshot {
            interactive: read(&self.shared.admission.interactive, iq),
            bulk: read(&self.shared.admission.bulk, bq),
        }
    }

    /// Drain queued work and stop the workers. Idempotent: a second call
    /// (or the implicit one from `Drop`) finds the worker pool already
    /// drained and returns immediately — the network server's shutdown
    /// path and `Drop` may both get here without panicking or
    /// deadlocking.
    pub fn shutdown(&self) {
        self.stop_inner();
        let mut workers = self.workers.lock().unwrap();
        for w in workers.drain(..) {
            let _ = w.join();
        }
    }

    fn stop_inner(&self) {
        self.shared.queues.lock().unwrap().stopping = true;
        self.shared.cv.notify_all();
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Fetch (or lazily create) the session of one stream.
fn stream_session(shared: &Arc<Shared>, engine: &Engine, stream: usize) -> Arc<Session> {
    let mut slots = shared.sessions.lock().unwrap();
    if slots.len() <= stream {
        slots.resize_with(stream + 1, || None);
    }
    slots[stream]
        .get_or_insert_with(|| Arc::new(engine.new_session()))
        .clone()
}

fn worker_loop(shared: Arc<Shared>, engine: Engine, wcfg: WorkerCfg) {
    let mut jobs: Vec<Job> = Vec::new();
    loop {
        jobs.clear();
        {
            let mut guard = shared.queues.lock().unwrap();
            loop {
                // Priority: the interactive queue (earliest deadline
                // first) before bulk; streams with an in-flight request
                // are skipped so per-stream order holds.
                let q = &mut *guard;
                if let Some(j) = pop_ready_edf(&mut q.interactive, &q.busy, false) {
                    q.busy.insert(j.stream());
                    jobs.push(j);
                    break;
                }
                if let Some(j) = pop_ready(&mut q.bulk, &q.busy) {
                    q.busy.insert(j.stream());
                    jobs.push(j);
                    break;
                }
                if q.stopping {
                    break;
                }
                guard = shared.cv.wait(guard).unwrap();
            }
            // Cross-stream decode batching: keep collecting ready
            // decodes (earliest deadline first — the busy guard already
            // enforces at most one per stream) up to `max_batch`,
            // waiting out the bounded window for more to arrive.
            // Prefills never batch.
            let decode_lead = jobs.first().is_some_and(|j| j.request.is_decode());
            if wcfg.batching() && decode_lead {
                let deadline = Instant::now() + wcfg.window;
                loop {
                    {
                        let q = &mut *guard;
                        while jobs.len() < wcfg.max_batch {
                            match pop_ready_edf(&mut q.interactive, &q.busy, true) {
                                Some(j) => {
                                    q.busy.insert(j.stream());
                                    jobs.push(j);
                                }
                                None => break,
                            }
                        }
                    }
                    if jobs.len() >= wcfg.max_batch || guard.stopping {
                        break;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    guard = shared.cv.wait_timeout(guard, deadline - now).unwrap().0;
                }
            }
        }
        if jobs.is_empty() {
            return; // stopping, nothing left to serve
        }
        if jobs.len() == 1 {
            let job = jobs.pop().expect("one job claimed");
            if !job.request.is_decode() && wcfg.prefill_chunk > 0 {
                run_prefill_chunked(&shared, &engine, wcfg, job);
            } else {
                run_single(&shared, &engine, job);
            }
        } else {
            run_decode_batch(&shared, &engine, &mut jobs);
        }
        // Online cache adaptation rides the serving loop: every Nth
        // completed job one worker runs a maintenance pass (admissions
        // from live selection frequency, drift check, possible
        // re-reorder). One relaxed atomic when the cache is off.
        engine.cache_tick();
    }
}

/// Release a finished job's stream (and any budget tokens it held) and
/// wake waiters (notify_all: the waiter isn't necessarily the one the
/// submit-side notify_one woke).
fn release_stream(shared: &Arc<Shared>, stream: usize, tokens: usize) {
    {
        let mut q = shared.queues.lock().unwrap();
        q.busy.remove(&stream);
        q.release_tokens(stream, tokens);
    }
    shared.cv.notify_all();
}

/// Serve one request on its stream's session and deliver the completion.
fn run_single(shared: &Arc<Shared>, engine: &Engine, job: Job) {
    let queue_wait = job.enqueued.elapsed();
    shared.admission.record_served(job.class, queue_wait);
    let session = stream_session(shared, engine, job.stream());
    let t0 = Instant::now();
    let (output, stats) = match &job.request {
        Request::Prefill { frame, .. } => match session.append_frame(frame) {
            Ok((y, s)) => (Ok(y), s),
            Err(e) => (Err(e.to_string()), StageStats::default()),
        },
        Request::Decode { token, .. } => match session.decode_step(token) {
            Ok((y, s)) => (Ok(y), s),
            Err(e) => (Err(e.to_string()), StageStats::default()),
        },
    };
    let stream = job.stream();
    let _ = job.done.send(Completion {
        stream,
        kind: job.request.name(),
        output,
        stats,
        queue_wait,
        exec_wall: t0.elapsed(),
    });
    release_stream(shared, stream, job.tokens);
}

/// Serve one prefill through the resumable chunked driver, interleaving
/// ready decode work at every yield point: after each `chunk`-layer
/// step the worker serves at most one decode batch (or solo decode)
/// from the interactive queue, so both classes make bounded progress —
/// a decode arriving mid-prefill waits for the current *chunk*, not the
/// whole pass. Outputs are bit-identical to the monolithic path.
fn run_prefill_chunked(shared: &Arc<Shared>, engine: &Engine, wcfg: WorkerCfg, job: Job) {
    let queue_wait = job.enqueued.elapsed();
    shared.admission.record_served(job.class, queue_wait);
    let stream = job.stream();
    let session = stream_session(shared, engine, stream);
    let Request::Prefill { frame, .. } = &job.request else {
        // Decode jobs never reach this driver (the worker loop routes
        // them to run_single / run_decode_batch).
        unreachable!("chunked driver serves prefills only");
    };
    let t0 = Instant::now();
    let mut out = Vec::new();
    let result = (|| -> Result<StageStats, anyhow::Error> {
        session.prefill_begin(frame)?;
        while session.prefill_step(wcfg.prefill_chunk)? {
            // Yield point: every engine lock is released here.
            serve_interleaved_decodes(shared, engine, wcfg);
        }
        session.prefill_finish(&mut out)
    })();
    let (output, stats) = match result {
        Ok(stats) => (Ok(std::mem::take(&mut out)), stats),
        Err(e) => {
            // A failed step already reset the session; make abort
            // unconditional so no half-appended KV ever survives.
            session.prefill_abort();
            (Err(e.to_string()), StageStats::default())
        }
    };
    let _ = job.done.send(Completion {
        stream,
        kind: job.request.name(),
        output,
        stats,
        queue_wait,
        exec_wall: t0.elapsed(),
    });
    release_stream(shared, stream, job.tokens);
}

/// Serve at most one round of ready decode work (a fused batch when
/// batching is on and several are ready, else one solo decode) without
/// waiting: called between prefill chunks, where blocking on the batch
/// window would defeat the interleave. The prefill's own stream is in
/// the busy set, so its queued requests are never lifted mid-pass.
fn serve_interleaved_decodes(shared: &Arc<Shared>, engine: &Engine, wcfg: WorkerCfg) {
    let mut jobs: Vec<Job> = Vec::new();
    {
        let mut q = shared.queues.lock().unwrap();
        let cap = if wcfg.batching() { wcfg.max_batch } else { 1 };
        while jobs.len() < cap {
            match pop_ready_edf(&mut q.interactive, &q.busy, true) {
                Some(j) => {
                    q.busy.insert(j.stream());
                    jobs.push(j);
                }
                None => break,
            }
        }
    }
    if jobs.is_empty() {
        return;
    }
    if jobs.len() == 1 {
        let job = jobs.pop().expect("one job claimed");
        run_single(shared, engine, job);
    } else {
        run_decode_batch(shared, engine, &mut jobs);
    }
}

/// Serve a group of decode jobs (distinct streams) as one fused batch;
/// every member gets its own completion.
///
/// Members that would fail the batch's all-or-nothing validation for a
/// *predictable* reason (no primed KV yet) are screened out up front and
/// served solo, so they get their own error while the rest still batch.
/// If the fused batch itself errors, the members are retried **solo**:
/// a failed [`Engine::decode_batch_into`] rolls every member's KV back
/// to its pre-batch state (transactional), so re-decoding the same
/// token solo is safe and bit-identical to having never batched. The
/// stream that actually carries the fault (e.g. its selection needs an
/// extent only a dead member holds) gets its own error completion while
/// the innocent members still complete.
fn run_decode_batch(shared: &Arc<Shared>, engine: &Engine, jobs: &mut Vec<Job>) {
    let streams: Vec<usize> = jobs.iter().map(|j| j.stream()).collect();
    let sessions: Vec<Arc<Session>> = jobs
        .iter()
        .map(|j| stream_session(shared, engine, j.stream()))
        .collect();
    let waits: Vec<Duration> = jobs.iter().map(|j| j.enqueued.elapsed()).collect();
    for (job, wait) in jobs.iter().zip(&waits) {
        shared.admission.record_served(job.class, *wait);
    }

    // Screen out members that cannot decode yet; serve them solo for
    // their own per-stream error (or result, if a frame landed
    // in-between). `ready` keeps (job index) of the batchable rest.
    let mut ready: Vec<usize> = Vec::with_capacity(jobs.len());
    let mut solo_done: Vec<(usize, Result<Vec<f32>, String>, StageStats, Duration)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        if sessions[i].kv_tokens() > 0 {
            ready.push(i);
            continue;
        }
        let Request::Decode { token, .. } = &job.request else {
            unreachable!("batches hold decode requests only");
        };
        let t0 = Instant::now();
        let (output, st) = match sessions[i].decode_step(token) {
            Ok((y, s)) => (Ok(y), s),
            Err(e) => (Err(e.to_string()), StageStats::default()),
        };
        solo_done.push((i, output, st, t0.elapsed()));
    }

    let mut outs = vec![Vec::new(); ready.len()];
    let mut stats = vec![StageStats::default(); ready.len()];
    let t0 = Instant::now();
    let batch_result = if ready.is_empty() {
        Ok(())
    } else {
        let reqs: Vec<DecodeRequest> = ready
            .iter()
            .map(|&i| {
                let Request::Decode { token, .. } = &jobs[i].request else {
                    unreachable!("batches hold decode requests only");
                };
                DecodeRequest {
                    session: &sessions[i],
                    token,
                }
            })
            .collect();
        engine.decode_batch_into(&reqs, &mut outs, &mut stats)
    };
    let exec_wall = t0.elapsed();

    // Deliver the batch members' completions. A failed batch rolled
    // every member's KV back, so each member is retried solo: innocent
    // streams complete normally and only the faulty one carries the
    // error.
    for (bi, &i) in ready.iter().enumerate() {
        let (output, st, wall) = match &batch_result {
            Ok(()) => (Ok(std::mem::take(&mut outs[bi])), stats[bi], exec_wall),
            Err(_) => {
                let Request::Decode { token, .. } = &jobs[i].request else {
                    unreachable!("batches hold decode requests only");
                };
                let solo_t0 = Instant::now();
                match sessions[i].decode_step(token) {
                    Ok((y, s)) => (Ok(y), s, exec_wall + solo_t0.elapsed()),
                    Err(e) => (
                        Err(e.to_string()),
                        StageStats::default(),
                        exec_wall + solo_t0.elapsed(),
                    ),
                }
            }
        };
        let job = &jobs[i];
        let _ = job.done.send(Completion {
            stream: job.stream(),
            kind: "decode",
            output,
            stats: st,
            queue_wait: waits[i],
            exec_wall: wall,
        });
    }
    // And the screened-out members' solo completions.
    for (i, output, st, wall) in solo_done {
        let job = &jobs[i];
        let _ = job.done.send(Completion {
            stream: job.stream(),
            kind: "decode",
            output,
            stats: st,
            queue_wait: waits[i],
            exec_wall: wall,
        });
    }
    jobs.clear();

    // Release every member stream at once (decode jobs hold no budget
    // tokens).
    {
        let mut q = shared.queues.lock().unwrap();
        for s in &streams {
            q.busy.remove(s);
        }
    }
    shared.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Single-worker config regardless of NC_SCHED_WORKERS: these tests
    /// assert strict serial-execution properties.
    fn serial_cfg() -> SchedulerConfig {
        SchedulerConfig::default().with_workers(1)
    }

    fn spawn_tiny_cfg(cfg: SchedulerConfig) -> Scheduler {
        Scheduler::spawn(cfg, move || {
            Engine::builder("tiny")
                .policy(Policy::TopK)
                .sparsity(0.3)
                .artifacts(&artifact_dir())
                .build()
                .unwrap()
        })
    }

    fn spawn_tiny() -> Scheduler {
        spawn_tiny_cfg(SchedulerConfig::default())
    }

    fn tiny_frame() -> Vec<f32> {
        crate::workload::FrameTrace::new(64, 8, 4, 3).frame(0)
    }

    #[test]
    fn processes_prefill_and_decode() {
        let s = spawn_tiny();
        let rx = s.submit(Request::prefill(0, tiny_frame())).unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.kind, "prefill");
        let y = c.output.unwrap();
        assert_eq!(y.len(), 8 * 64);
        let rx = s.submit(Request::decode(0, vec![0.1; 64])).unwrap();
        let c = rx.recv().unwrap();
        assert!(c.output.is_ok());
        assert!(c.stats.io > Duration::ZERO);
        s.shutdown();
    }

    #[test]
    fn decode_preempts_queued_prefills() {
        let s = spawn_tiny_cfg(serial_cfg());
        // Prime stream 0 so decode is legal (decode preempts *everything*,
        // including a not-yet-started priming prefill, so wait for it).
        let first = s.submit(Request::prefill(0, tiny_frame())).unwrap();
        first.recv().unwrap().output.unwrap();
        // Queue: several prefills on stream 1, then a decode on stream 0.
        // The worker may already be chewing on the first queued prefill,
        // but the decode must jump ahead of the later ones.
        let prefill_rxs: Vec<_> = (0..3)
            .map(|_| s.submit(Request::prefill(1, tiny_frame())).unwrap())
            .collect();
        let decode_rx = s.submit(Request::decode(0, vec![0.05; 64])).unwrap();
        let d = decode_rx.recv().unwrap();
        d.output.clone().unwrap();
        // The decode must have waited less than the last queued prefill.
        let last_prefill = prefill_rxs.last().unwrap().recv().unwrap();
        assert!(
            d.queue_wait <= last_prefill.queue_wait,
            "decode waited {:?}, prefill {:?}",
            d.queue_wait,
            last_prefill.queue_wait
        );
        s.shutdown();
    }

    #[test]
    fn backpressure() {
        let s = Scheduler::spawn(
            SchedulerConfig::default().with_max_queue(2).with_workers(1),
            || {
                Engine::builder("tiny")
                    .artifacts(&artifact_dir())
                    .build()
                    .unwrap()
            },
        );
        // Saturate: the worker takes the first, queue holds two more.
        let mut rxs = Vec::new();
        let mut rejected = false;
        for _ in 0..8 {
            match s.submit(Request::prefill(0, tiny_frame())) {
                Ok(rx) => rxs.push(rx),
                Err(e) => {
                    assert!(matches!(e, SubmitError::QueueFull { .. }), "got {e}");
                    assert!(e.retry_after().is_some());
                    rejected = true;
                    break;
                }
            }
        }
        assert!(rejected, "queue should overflow");
        for rx in rxs {
            rx.recv().unwrap().output.unwrap();
        }
        s.shutdown();
    }

    #[test]
    fn errors_surface_in_completion() {
        let s = spawn_tiny();
        // Decode without prior prefill is an engine error, not a crash.
        let rx = s.submit(Request::decode(0, vec![0.0; 64])).unwrap();
        let c = rx.recv().unwrap();
        assert!(c.output.is_err());
        s.shutdown();
    }

    #[test]
    fn out_of_bounds_stream_rejected() {
        let s = Scheduler::spawn(SchedulerConfig::default().with_max_streams(2), || {
            Engine::builder("tiny")
                .artifacts(&artifact_dir())
                .build()
                .unwrap()
        });
        match s.submit(Request::prefill(2, tiny_frame())) {
            Err(SubmitError::UnknownStream {
                stream: 2,
                max_streams: 2,
            }) => {}
            other => panic!("expected UnknownStream, got {other:?}"),
        }
        s.shutdown();
    }

    #[test]
    fn same_stream_requests_stay_ordered_across_workers() {
        // Pipelined prefills on ONE stream with a 4-worker pool: the
        // per-stream in-flight guard must keep them in submission order
        // (KV state makes every output order-sensitive).
        let s = spawn_tiny_cfg(SchedulerConfig::default().with_workers(4));
        let trace = crate::workload::FrameTrace::new(64, 8, 8, 3);
        let rxs: Vec<_> = (0..4)
            .map(|f| s.submit(Request::prefill(0, trace.frame(f))).unwrap())
            .collect();
        let outs: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().output.unwrap())
            .collect();
        s.shutdown();
        let reference = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        let session = reference.new_session();
        for (f, out) in outs.iter().enumerate() {
            let (want, _) = session.append_frame(&trace.frame(f)).unwrap();
            assert_eq!(out, &want, "frame {f} executed out of order");
        }
    }

    #[test]
    fn shutdown_with_queued_requests_drains_cleanly() {
        // Satellite regression: shutdown while requests are still queued
        // must not deadlock any worker, and every submitted request must
        // either complete or be cleanly rejected (its channel
        // disconnects) — never hang.
        let s = spawn_tiny_cfg(serial_cfg());
        let rxs: Vec<_> = (0..6)
            .map(|i| s.submit(Request::prefill(i % 3, tiny_frame())).unwrap())
            .collect();
        // Shut down immediately: the single worker is at most one job
        // in; the rest are still queued.
        s.shutdown();
        let mut completed = 0usize;
        let mut rejected = 0usize;
        for rx in rxs {
            // After shutdown() joined the workers, every sender side is
            // either used or dropped, so recv() cannot block.
            match rx.recv() {
                Ok(c) => {
                    c.output.unwrap();
                    completed += 1;
                }
                Err(_) => rejected += 1,
            }
        }
        assert_eq!(completed + rejected, 6);
        // The drain semantics deliver everything that was queued before
        // the stop flag was observed.
        assert!(completed >= 1, "at least the in-flight job completes");
    }

    #[test]
    fn shutdown_is_idempotent() {
        // Satellite regression: the network server's signal path and
        // `Drop` may both call shutdown — the second call (and the
        // implicit Drop after both) must neither panic nor deadlock,
        // and submits after shutdown must be clean errors.
        let s = spawn_tiny_cfg(serial_cfg());
        let rx = s.submit(Request::prefill(0, tiny_frame())).unwrap();
        rx.recv().unwrap().output.unwrap();
        s.shutdown();
        s.shutdown();
        match s.submit(Request::prefill(0, tiny_frame())) {
            Err(SubmitError::Stopping) => {}
            other => panic!("submit after shutdown must be Stopping, got {other:?}"),
        }
        drop(s); // third stop via Drop — still clean
    }

    #[test]
    fn batched_decodes_match_solo_reference() {
        // One worker + a batching window: four decode requests from four
        // primed streams coalesce into fused batches, and every stream's
        // output must be bit-identical to a solo single-session
        // reference.
        let s = spawn_tiny_cfg(
            SchedulerConfig::default()
                .with_workers(1)
                .with_batch_window(Duration::from_millis(500))
                .with_max_batch(4),
        );
        let trace = crate::workload::FrameTrace::new(64, 8, 8, 3);
        // Prime each stream with its own frame.
        let rxs: Vec<_> = (0..4)
            .map(|stream| s.submit(Request::prefill(stream, trace.frame(stream))).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().output.unwrap();
        }
        // Two decode rounds; submissions land fast enough to batch.
        let token = vec![0.04f32; 64];
        let mut rounds: Vec<Vec<Vec<f32>>> = Vec::new();
        for _ in 0..2 {
            let rxs: Vec<_> = (0..4)
                .map(|stream| s.submit(Request::decode(stream, token.clone())).unwrap())
                .collect();
            rounds.push(
                rxs.into_iter()
                    .map(|rx| rx.recv().unwrap().output.unwrap())
                    .collect(),
            );
        }
        // Batches actually formed (occupancy metric counts members).
        let m = s.engine().metrics();
        assert!(
            m.bytes("batch.occupancy") >= 2,
            "expected at least one fused batch, got occupancy bytes {}",
            m.bytes("batch.occupancy")
        );
        s.shutdown();
        // Reference: identical engine, solo sessions per stream — the
        // batched outputs must be bit-identical.
        let reference = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        for stream in 0..4usize {
            let session = reference.new_session();
            session.append_frame(&trace.frame(stream)).unwrap();
            for (round, outs) in rounds.iter().enumerate() {
                let (want, _) = session.decode_step(&token).unwrap();
                assert_eq!(
                    outs[stream], want,
                    "stream {stream} diverged under batching at round {round}"
                );
            }
        }
    }

    #[test]
    fn batched_fallback_isolates_invalid_streams() {
        // Stream 1 decodes without a primed KV: the batch falls back to
        // solo decodes, stream 1 gets its error, stream 0 still
        // completes.
        let s = spawn_tiny_cfg(
            SchedulerConfig::default()
                .with_workers(1)
                .with_batch_window(Duration::from_millis(300))
                .with_max_batch(4),
        );
        let prime = s.submit(Request::prefill(0, tiny_frame())).unwrap();
        prime.recv().unwrap().output.unwrap();
        let good = s.submit(Request::decode(0, vec![0.02; 64])).unwrap();
        let bad = s.submit(Request::decode(1, vec![0.02; 64])).unwrap();
        assert!(good.recv().unwrap().output.is_ok());
        assert!(bad.recv().unwrap().output.is_err());
        s.shutdown();
    }

    #[test]
    fn fused_batch_device_error_isolates_faulty_stream() {
        // A persistent injected device error during a fused batch must
        // produce exactly one error completion: the fused attempt burns
        // READ_ATTEMPTS reads, rolls every member back (transactional
        // decode_batch), and the scheduler retries each stream solo —
        // the first solo retry burns the remaining READ_ATTEMPTS and
        // errors, the rest see a healthy device and complete with
        // outputs bit-identical to a fault-free reference.
        use crate::storage::{FaultConfig, READ_ATTEMPTS};
        let build = || {
            Engine::builder("tiny")
                .policy(Policy::TopK)
                .sparsity(0.3)
                .devices(1)
                .exec_threads(1)
                .prefetch(false)
                .async_io(false)
                .artifacts(&artifact_dir())
                .build()
                .unwrap()
        };
        let engine = build();
        let fault = engine.inject_faults(0, FaultConfig::default());
        let s = Scheduler::spawn(
            SchedulerConfig::default()
                .with_workers(1)
                .with_batch_window(Duration::from_millis(300))
                .with_max_batch(4),
            move || engine,
        );
        let trace = crate::workload::FrameTrace::new(64, 8, 4, 3);
        for stream in 0..3usize {
            s.submit(Request::prefill(stream, trace.frame(stream)))
                .unwrap()
                .recv()
                .unwrap()
                .output
                .unwrap();
        }
        let token = vec![0.02f32; 64];
        let rxs: Vec<_> = (0..3)
            .map(|stream| s.submit(Request::decode(stream, token.clone())).unwrap())
            .collect();
        // Armed inside the batch window (the worker is still collecting
        // arrivals), so the whole budget lands on the fused execution.
        fault.fail_next(2 * READ_ATTEMPTS as u64);
        let outs: Vec<Result<Vec<f32>, String>> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().output).collect();
        s.shutdown();
        let errs: Vec<bool> = outs.iter().map(Result::is_err).collect();
        assert_eq!(
            errs.iter().filter(|&&e| e).count(),
            1,
            "exactly one stream absorbs the persistent fault: {errs:?}"
        );
        let reference = build();
        for (stream, out) in outs.iter().enumerate() {
            if let Ok(y) = out {
                let session = reference.new_session();
                session.append_frame(&trace.frame(stream)).unwrap();
                let (want, _) = session.decode_step(&token).unwrap();
                assert_eq!(y, &want, "stream {stream} diverged after batch fault recovery");
            }
        }
    }

    #[test]
    fn worker_pool_serves_streams_concurrently_and_correctly() {
        // 4 workers, 4 streams: per-stream outputs must match a serial
        // single-session reference exactly (stream isolation under
        // concurrency), and every request must complete.
        let s = spawn_tiny_cfg(SchedulerConfig::default().with_workers(4));
        assert_eq!(s.workers(), 4);
        let frames: Vec<Vec<f32>> = (0..4)
            .map(|i| crate::workload::FrameTrace::new(64, 8, 8, 3).frame(i))
            .collect();
        let rxs: Vec<_> = (0..4)
            .map(|stream| s.submit(Request::prefill(stream, frames[stream].clone())).unwrap())
            .collect();
        let outs: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().output.unwrap())
            .collect();
        // Decodes on every stream, concurrently.
        let drxs: Vec<_> = (0..4)
            .map(|stream| s.submit(Request::decode(stream, vec![0.02; 64])).unwrap())
            .collect();
        for rx in drxs {
            rx.recv().unwrap().output.unwrap();
        }
        s.shutdown();
        // Reference: an identically-built engine, one serial session per
        // stream (deterministic weights per seed ⇒ identical outputs).
        let reference = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        for (stream, out) in outs.iter().enumerate() {
            let session = reference.new_session();
            let (want, _) = session.append_frame(&frames[stream]).unwrap();
            assert_eq!(out, &want, "stream {stream} diverged under concurrency");
        }
    }

    #[test]
    fn chunked_prefill_is_bit_identical_to_monolithic() {
        // The tentpole invariant: the resumable chunked driver (any
        // chunk size) produces outputs and downstream decode state
        // bit-identical to the monolithic path.
        let trace = crate::workload::FrameTrace::new(64, 8, 8, 3);
        let token = vec![0.03f32; 64];
        let run = |chunk: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let s = spawn_tiny_cfg(serial_cfg().with_prefill_chunk(chunk));
            let a = s
                .submit(Request::prefill(0, trace.frame(0)))
                .unwrap()
                .recv()
                .unwrap()
                .output
                .unwrap();
            let b = s
                .submit(Request::prefill(0, trace.frame(1)))
                .unwrap()
                .recv()
                .unwrap()
                .output
                .unwrap();
            let d = s
                .submit(Request::decode(0, token.clone()))
                .unwrap()
                .recv()
                .unwrap()
                .output
                .unwrap();
            s.shutdown();
            (a, b, d)
        };
        let mono = run(0);
        for chunk in [1usize, 2, 3] {
            let chunked = run(chunk);
            assert_eq!(mono.0, chunked.0, "chunk {chunk}: first prefill diverged");
            assert_eq!(mono.1, chunked.1, "chunk {chunk}: second prefill diverged");
            assert_eq!(mono.2, chunked.2, "chunk {chunk}: decode after chunked prefill diverged");
        }
    }

    #[test]
    fn decode_interleaves_into_chunked_prefill() {
        // One worker, chunk 1: a decode submitted while a long prefill
        // runs must complete *before* the prefill does (served at a
        // yield point), with output bit-identical to solo.
        let s = spawn_tiny_cfg(serial_cfg().with_prefill_chunk(1));
        let trace = crate::workload::FrameTrace::new(64, 8, 8, 3);
        // Prime stream 0, then occupy the worker with prefills on
        // stream 1 while decoding stream 0.
        s.submit(Request::prefill(0, trace.frame(0)))
            .unwrap()
            .recv()
            .unwrap()
            .output
            .unwrap();
        let prefill_rxs: Vec<_> = (0..4)
            .map(|_| s.submit(Request::prefill(1, trace.frame(1))).unwrap())
            .collect();
        let token = vec![0.05f32; 64];
        let d = s
            .submit(Request::decode(0, token.clone()))
            .unwrap()
            .recv()
            .unwrap();
        let y = d.output.unwrap();
        for rx in prefill_rxs {
            rx.recv().unwrap().output.unwrap();
        }
        // The interleave path actually ran (yield points were taken).
        let yields = s.engine().metrics().bytes("prefill.yields");
        assert!(yields > 0, "expected chunked-prefill yields, got {yields}");
        s.shutdown();
        let reference = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.3)
            .artifacts(&artifact_dir())
            .build()
            .unwrap();
        let session = reference.new_session();
        session.append_frame(&trace.frame(0)).unwrap();
        let (want, _) = session.decode_step(&token).unwrap();
        assert_eq!(y, want, "interleaved decode diverged from solo reference");
    }

    #[test]
    fn slo_sheds_and_recovers() {
        // Tight SLO + slow queue: once the bulk queue's oldest request
        // is older than the SLO, further prefill submits shed with a
        // typed, retryable error — and admission recovers after drain.
        let s = spawn_tiny_cfg(
            serial_cfg()
                .with_slo(Some(Duration::from_millis(1)))
                .with_batch_window(Duration::ZERO),
        );
        let mut rxs = Vec::new();
        let mut shed = None;
        for i in 0..64 {
            match s.submit(Request::prefill(2 + (i % 8), tiny_frame())) {
                Ok(rx) => rxs.push(rx),
                Err(e) => {
                    shed = Some(e);
                    break;
                }
            }
            // Give the queue time to age past the 1ms SLO.
            std::thread::sleep(Duration::from_millis(2));
        }
        let shed = shed.expect("prefill flood must eventually shed");
        assert!(shed.is_shed(), "expected a 429-class shed, got {shed}");
        assert!(shed.retry_after().is_some());
        assert!(s.admission().bulk.shed >= 1);
        // Drain, then admission must recover.
        for rx in rxs {
            let _ = rx.recv();
        }
        let rx = s
            .submit(Request::prefill(1, tiny_frame()))
            .expect("admission recovers after drain");
        rx.recv().unwrap().output.unwrap();
        s.shutdown();
    }

    #[test]
    fn prefill_budget_sheds_per_stream() {
        // Budget of one frame's tokens: a second queued prefill on the
        // same stream sheds, while another stream still admits.
        let s = spawn_tiny_cfg(serial_cfg().with_prefill_budget(8));
        // Occupy the worker so queued jobs stay queued.
        let block = s.submit(Request::prefill(0, tiny_frame())).unwrap();
        let queued = s.submit(Request::prefill(1, tiny_frame())).unwrap();
        let second = s.submit(Request::prefill(1, tiny_frame()));
        match second {
            Err(SubmitError::BudgetExhausted {
                stream: 1,
                queued_tokens: 8,
                budget: 8,
                ..
            }) => {}
            other => panic!("expected BudgetExhausted for stream 1, got {other:?}"),
        }
        // A different stream is not affected by stream 1's budget.
        let other = s.submit(Request::prefill(2, tiny_frame())).unwrap();
        for rx in [block, queued, other] {
            rx.recv().unwrap().output.unwrap();
        }
        // Budget released after completion: stream 1 admits again.
        let rx = s.submit(Request::prefill(1, tiny_frame())).unwrap();
        rx.recv().unwrap().output.unwrap();
        assert!(s.admission().bulk.shed >= 1);
        s.shutdown();
    }

    #[test]
    fn deadline_orders_interactive_queue() {
        // Two decodes queued behind a busy worker: the one with the
        // tighter deadline runs first even though it was submitted
        // second (EDF), so it waits less.
        let s = spawn_tiny_cfg(serial_cfg().with_prefill_chunk(0));
        let trace = crate::workload::FrameTrace::new(64, 8, 8, 3);
        for stream in 0..2 {
            s.submit(Request::prefill(stream, trace.frame(stream)))
                .unwrap()
                .recv()
                .unwrap()
                .output
                .unwrap();
        }
        // Occupy the single worker with a monolithic prefill.
        let block = s.submit(Request::prefill(2, trace.frame(2))).unwrap();
        let relaxed = s
            .submit(
                Request::decode(0, vec![0.02; 64]).with_opts(RequestOpts {
                    deadline: Some(Duration::from_millis(400)),
                    ..RequestOpts::default()
                }),
            )
            .unwrap();
        let urgent = s
            .submit(
                Request::decode(1, vec![0.02; 64]).with_opts(RequestOpts {
                    deadline: Some(Duration::from_millis(1)),
                    ..RequestOpts::default()
                }),
            )
            .unwrap();
        let relaxed = relaxed.recv().unwrap();
        let urgent = urgent.recv().unwrap();
        block.recv().unwrap().output.unwrap();
        relaxed.output.unwrap();
        urgent.output.unwrap();
        assert!(
            urgent.queue_wait < relaxed.queue_wait,
            "urgent decode waited {:?}, relaxed {:?}",
            urgent.queue_wait,
            relaxed.queue_wait
        );
        s.shutdown();
    }

    #[test]
    fn class_override_promotes_prefill() {
        // A prefill marked interactive jumps the bulk queue: behind a
        // busy worker, it runs before bulk prefills submitted earlier.
        let s = spawn_tiny_cfg(serial_cfg());
        let trace = crate::workload::FrameTrace::new(64, 8, 8, 3);
        let block = s.submit(Request::prefill(0, trace.frame(0))).unwrap();
        let bulk = s.submit(Request::prefill(1, trace.frame(1))).unwrap();
        let promoted = s
            .submit(
                Request::prefill(2, trace.frame(2)).with_opts(RequestOpts {
                    class: Some(Class::Interactive),
                    ..RequestOpts::default()
                }),
            )
            .unwrap();
        let bulk = bulk.recv().unwrap();
        let promoted = promoted.recv().unwrap();
        block.recv().unwrap().output.unwrap();
        bulk.output.unwrap();
        promoted.output.unwrap();
        assert!(
            promoted.queue_wait < bulk.queue_wait,
            "promoted prefill waited {:?}, bulk {:?}",
            promoted.queue_wait,
            bulk.queue_wait
        );
        s.shutdown();
    }
}
