//! Request scheduler: multi-stream frame-append/decode traffic over one
//! engine (one flash device = one execution lane, the edge reality).
//!
//! Decode steps are latency-critical (a user is waiting on tokens) and
//! preempt queued frame appends — the standard serving-priority split.
//! The engine is constructed *inside* the worker thread (engine cores are
//! thread-confined); each stream index lazily gets its own [`Session`],
//! and callers talk through channels.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{Engine, Session, StageStats};

/// What a request asks the engine to do.
#[derive(Clone, Debug)]
pub enum RequestKind {
    /// Append a frame of token embeddings ([T, d] row-major).
    AppendFrame(Vec<f32>),
    /// Decode one token from its embedding ([d]).
    Decode(Vec<f32>),
}

impl RequestKind {
    pub fn name(&self) -> &'static str {
        match self {
            RequestKind::AppendFrame(_) => "append",
            RequestKind::Decode(_) => "decode",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub stream: usize,
    pub kind: RequestKind,
}

/// Completed request: output hidden states + accounting.
#[derive(Clone, Debug)]
pub struct Completion {
    pub stream: usize,
    pub kind: &'static str,
    pub output: Result<Vec<f32>, String>,
    pub stats: StageStats,
    /// Time spent queued before execution started.
    pub queue_wait: Duration,
    /// Execution wall time (includes virtual-I/O accounting only in
    /// `stats`, not here).
    pub exec_wall: Duration,
}

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Maximum queued requests before `submit` returns an error
    /// (backpressure).
    pub max_queue: usize,
    /// Maximum distinct stream indices (sessions are created lazily up to
    /// this bound; requests beyond it are rejected at submit).
    pub max_streams: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            max_queue: 256,
            max_streams: 64,
        }
    }
}

struct Job {
    request: Request,
    enqueued: Instant,
    done: Sender<Completion>,
}

#[derive(Default)]
struct Queues {
    decode: VecDeque<Job>,
    append: VecDeque<Job>,
    stopping: bool,
}

impl Queues {
    fn len(&self) -> usize {
        self.decode.len() + self.append.len()
    }
}

struct Shared {
    queues: Mutex<Queues>,
    cv: Condvar,
}

/// Thread-backed scheduler around an [`Engine`].
pub struct Scheduler {
    shared: Arc<Shared>,
    cfg: SchedulerConfig,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Scheduler {
    /// Spawn the worker; `make_engine` runs on the worker thread (engine
    /// state is thread-confined).
    pub fn spawn<F>(cfg: SchedulerConfig, make_engine: F) -> Self
    where
        F: FnOnce() -> Engine + Send + 'static,
    {
        let shared = Arc::new(Shared {
            queues: Mutex::new(Queues::default()),
            cv: Condvar::new(),
        });
        let worker_shared = shared.clone();
        let worker = std::thread::spawn(move || {
            let engine = make_engine();
            let mut sessions: Vec<Session> = Vec::new();
            loop {
                let job = {
                    let mut q = worker_shared.queues.lock().unwrap();
                    loop {
                        // Priority: decode before append.
                        if let Some(j) = q.decode.pop_front() {
                            break Some(j);
                        }
                        if let Some(j) = q.append.pop_front() {
                            break Some(j);
                        }
                        if q.stopping {
                            break None;
                        }
                        q = worker_shared.cv.wait(q).unwrap();
                    }
                };
                let Some(job) = job else { return };
                let queue_wait = job.enqueued.elapsed();
                while sessions.len() <= job.request.stream {
                    sessions.push(engine.new_session());
                }
                let session = &sessions[job.request.stream];
                let t0 = Instant::now();
                let (output, stats) = match &job.request.kind {
                    RequestKind::AppendFrame(f) => match session.append_frame(f) {
                        Ok((y, s)) => (Ok(y), s),
                        Err(e) => (Err(e.to_string()), StageStats::default()),
                    },
                    RequestKind::Decode(tok) => match session.decode_step(tok) {
                        Ok((y, s)) => (Ok(y), s),
                        Err(e) => (Err(e.to_string()), StageStats::default()),
                    },
                };
                let _ = job.done.send(Completion {
                    stream: job.request.stream,
                    kind: job.request.kind.name(),
                    output,
                    stats,
                    queue_wait,
                    exec_wall: t0.elapsed(),
                });
            }
        });
        Self {
            shared,
            cfg,
            worker: Some(worker),
        }
    }

    /// Enqueue a request; returns the completion receiver, or an error if
    /// the queue is full (backpressure), the stream index is out of
    /// bounds, or the scheduler is stopping.
    pub fn submit(&self, request: Request) -> anyhow::Result<Receiver<Completion>> {
        anyhow::ensure!(
            request.stream < self.cfg.max_streams,
            "stream {} beyond max_streams {}",
            request.stream,
            self.cfg.max_streams
        );
        let (tx, rx) = std::sync::mpsc::channel();
        {
            let mut q = self.shared.queues.lock().unwrap();
            anyhow::ensure!(!q.stopping, "scheduler is stopping");
            anyhow::ensure!(
                q.len() < self.cfg.max_queue,
                "queue full ({} requests)",
                self.cfg.max_queue
            );
            let job = Job {
                request,
                enqueued: Instant::now(),
                done: tx,
            };
            match &job.request.kind {
                RequestKind::Decode(_) => q.decode.push_back(job),
                RequestKind::AppendFrame(_) => q.append.push_back(job),
            }
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    pub fn queued(&self) -> usize {
        self.shared.queues.lock().unwrap().len()
    }

    /// Drain queued work and stop the worker.
    pub fn shutdown(mut self) {
        self.stop_inner();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }

    fn stop_inner(&self) {
        self.shared.queues.lock().unwrap().stopping = true;
        self.shared.cv.notify_all();
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.stop_inner();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Policy;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn spawn_tiny() -> Scheduler {
        Scheduler::spawn(SchedulerConfig::default(), move || {
            Engine::builder("tiny")
                .policy(Policy::TopK)
                .sparsity(0.3)
                .artifacts(&artifact_dir())
                .build()
                .unwrap()
        })
    }

    fn tiny_frame() -> Vec<f32> {
        crate::workload::FrameTrace::new(64, 8, 4, 3).frame(0)
    }

    #[test]
    fn processes_append_and_decode() {
        let s = spawn_tiny();
        let rx = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::AppendFrame(tiny_frame()),
            })
            .unwrap();
        let c = rx.recv().unwrap();
        assert_eq!(c.kind, "append");
        let y = c.output.unwrap();
        assert_eq!(y.len(), 8 * 64);
        let rx = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::Decode(vec![0.1; 64]),
            })
            .unwrap();
        let c = rx.recv().unwrap();
        assert!(c.output.is_ok());
        assert!(c.stats.io > Duration::ZERO);
        s.shutdown();
    }

    #[test]
    fn decode_preempts_queued_appends() {
        let s = spawn_tiny();
        // Prime stream 0 so decode is legal (decode preempts *everything*,
        // including a not-yet-started priming append, so wait for it).
        let first = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::AppendFrame(tiny_frame()),
            })
            .unwrap();
        first.recv().unwrap().output.unwrap();
        // Queue: several appends on stream 1, then a decode on stream 0.
        // The worker may already be chewing on the first queued append,
        // but the decode must jump ahead of the later ones.
        let append_rxs: Vec<_> = (0..3)
            .map(|_| {
                s.submit(Request {
                    stream: 1,
                    kind: RequestKind::AppendFrame(tiny_frame()),
                })
                .unwrap()
            })
            .collect();
        let decode_rx = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::Decode(vec![0.05; 64]),
            })
            .unwrap();
        let d = decode_rx.recv().unwrap();
        d.output.clone().unwrap();
        // The decode must have waited less than the last queued append.
        let last_append = append_rxs.last().unwrap().recv().unwrap();
        assert!(
            d.queue_wait <= last_append.queue_wait,
            "decode waited {:?}, append {:?}",
            d.queue_wait,
            last_append.queue_wait
        );
        s.shutdown();
    }

    #[test]
    fn backpressure() {
        let s = Scheduler::spawn(
            SchedulerConfig {
                max_queue: 2,
                ..Default::default()
            },
            || {
                Engine::builder("tiny")
                    .artifacts(&artifact_dir())
                    .build()
                    .unwrap()
            },
        );
        // Saturate: worker takes the first, queue holds two more.
        let mut rxs = Vec::new();
        let mut rejected = false;
        for _ in 0..8 {
            match s.submit(Request {
                stream: 0,
                kind: RequestKind::AppendFrame(tiny_frame()),
            }) {
                Ok(rx) => rxs.push(rx),
                Err(_) => {
                    rejected = true;
                    break;
                }
            }
        }
        assert!(rejected, "queue should overflow");
        for rx in rxs {
            rx.recv().unwrap().output.unwrap();
        }
        s.shutdown();
    }

    #[test]
    fn errors_surface_in_completion() {
        let s = spawn_tiny();
        // Decode without prior append is an engine error, not a crash.
        let rx = s
            .submit(Request {
                stream: 0,
                kind: RequestKind::Decode(vec![0.0; 64]),
            })
            .unwrap();
        let c = rx.recv().unwrap();
        assert!(c.output.is_err());
        s.shutdown();
    }

    #[test]
    fn out_of_bounds_stream_rejected() {
        let s = Scheduler::spawn(
            SchedulerConfig {
                max_streams: 2,
                ..Default::default()
            },
            || {
                Engine::builder("tiny")
                    .artifacts(&artifact_dir())
                    .build()
                    .unwrap()
            },
        );
        assert!(s
            .submit(Request {
                stream: 2,
                kind: RequestKind::AppendFrame(tiny_frame()),
            })
            .is_err());
        s.shutdown();
    }
}
