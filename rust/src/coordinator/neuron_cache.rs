//! Hot-neuron caching under a memory budget (§5 "Leveraging Additional
//! Memory Budget for Caching").
//!
//! The cache pins the most frequently activated rows of each matrix in
//! RAM. Integration with selection is exactly the paper's: cached rows
//! are assigned zero importance before chunk selection (they cost nothing
//! to "load"), flash reads subtract cached rows from selected chunks, and
//! the compute gather serves them from memory.

use std::collections::HashMap;

use crate::latency::Chunk;
use crate::model::{MatrixId, MatrixKind, ModelSpec, WeightStore};
use crate::reorder::Permutation;

#[derive(Default)]
pub struct HotNeuronCache {
    /// Cached physical row indices per matrix (sorted).
    rows: HashMap<MatrixId, Vec<usize>>,
    /// Fast membership per matrix.
    member: HashMap<MatrixId, Vec<bool>>,
    /// Row weight data (runnable models only).
    data: HashMap<(MatrixId, usize), Vec<f32>>,
    bytes: u64,
}

impl HotNeuronCache {
    /// Build by caching the top-`fraction` most frequent rows of every
    /// scored group, up to `budget_bytes`. `freqs` maps scored-matrix id →
    /// per-physical-row activation frequency. Weight data is materialized
    /// from the store for runnable models.
    pub fn build(
        store: &WeightStore,
        freqs: &HashMap<MatrixId, Vec<f64>>,
        fraction: f64,
        budget_bytes: u64,
        materialize: bool,
    ) -> Self {
        let mut cache = Self::default();
        let spec: &ModelSpec = &store.spec;
        for layer in 0..spec.layers {
            for scored in MatrixKind::SCORED {
                let sid = MatrixId::new(layer, scored);
                let Some(freq) = freqs.get(&sid) else { continue };
                let rows = spec.shape_of(scored).rows;
                let take = ((rows as f64) * fraction) as usize;
                let mut order: Vec<usize> = (0..rows).collect();
                order.sort_by(|&a, &b| freq[b].total_cmp(&freq[a]));
                let mut chosen: Vec<usize> = order[..take.min(rows)].to_vec();
                chosen.sort_unstable();
                // Budget-check the *whole* group up front: members share
                // one selection mask, and the engine subtracts cached rows
                // from flash reads per group, so caching must be
                // all-or-nothing per group (a partially cached group would
                // leave uncached member rows unread). A group that doesn't
                // fit is skipped — later, smaller groups still fill the
                // budget instead of ending caching outright.
                let group_bytes: u64 = MatrixKind::ALL
                    .into_iter()
                    .filter(|m| m.mask_source() == scored)
                    .map(|m| {
                        store.layout.row_bytes(MatrixId::new(layer, m)) as u64
                            * chosen.len() as u64
                    })
                    .sum();
                if group_bytes == 0 || cache.bytes + group_bytes > budget_bytes {
                    continue;
                }
                // Apply to every member sharing this selection mask.
                for member in MatrixKind::ALL {
                    if member.mask_source() != scored {
                        continue;
                    }
                    let id = MatrixId::new(layer, member);
                    let row_bytes = store.layout.row_bytes(id) as u64;
                    cache.bytes += row_bytes * chosen.len() as u64;
                    let mut mask = vec![false; rows];
                    for &r in &chosen {
                        mask[r] = true;
                    }
                    if materialize {
                        let cols = spec.shape_of(member).cols;
                        let logical = store.logical_matrix(id);
                        for &r in &chosen {
                            let l = store
                                .permutation(id)
                                .map(|p| p.old_of(r))
                                .unwrap_or(r);
                            cache
                                .data
                                .insert((id, r), logical[l * cols..(l + 1) * cols].to_vec());
                        }
                    }
                    cache.member.insert(id, mask);
                    cache.rows.insert(id, chosen.clone());
                }
            }
        }
        cache
    }

    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    pub fn cached_rows(&self, id: MatrixId) -> &[usize] {
        self.rows.get(&id).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn is_cached(&self, id: MatrixId, row: usize) -> bool {
        self.member.get(&id).map(|m| m[row]).unwrap_or(false)
    }

    /// Zero the importance of cached rows (pre-selection step).
    pub fn zero_cached(&self, id: MatrixId, importance: &mut [f32]) {
        if let Some(m) = self.member.get(&id) {
            for (v, &c) in importance.iter_mut().zip(m) {
                if c {
                    *v = 0.0;
                }
            }
        }
    }

    /// Importance captured "for free" by the cache (physical row space
    /// mapped back through the permutation).
    pub fn cached_importance(
        &self,
        id: MatrixId,
        importance_logical: &[f32],
        perm: Option<&Permutation>,
    ) -> f64 {
        self.cached_rows(id)
            .iter()
            .map(|&p| {
                let l = perm.map(|pm| pm.old_of(p)).unwrap_or(p);
                importance_logical[l] as f64
            })
            .sum()
    }

    /// Split a selected chunk into the sub-chunks that still need flash
    /// reads (cached rows removed), *appending* them to `out` — the
    /// arena-backed form the serving hot path uses (no per-call
    /// allocation once `out` has capacity).
    pub fn subtract_cached_into(&self, id: MatrixId, chunk: Chunk, out: &mut Vec<Chunk>) {
        let Some(mask) = self.member.get(&id) else {
            out.push(chunk);
            return;
        };
        let mut start = None;
        for r in chunk.start..chunk.end() {
            if mask[r] {
                if let Some(s) = start.take() {
                    out.push(Chunk::new(s, r - s));
                }
            } else if start.is_none() {
                start = Some(r);
            }
        }
        if let Some(s) = start {
            out.push(Chunk::new(s, chunk.end() - s));
        }
    }

    pub fn row_data(&self, id: MatrixId, row: usize) -> Option<&[f32]> {
        self.data.get(&(id, row)).map(|v| v.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    fn store() -> WeightStore {
        WeightStore::new(ModelSpec::tiny(), false, 5)
    }

    fn freqs_for(store: &WeightStore) -> HashMap<MatrixId, Vec<f64>> {
        let mut f = HashMap::new();
        for layer in 0..store.spec.layers {
            for kind in MatrixKind::SCORED {
                let rows = store.spec.shape_of(kind).rows;
                f.insert(
                    MatrixId::new(layer, kind),
                    (0..rows).map(|i| (i % 7) as f64 / 7.0).collect(),
                );
            }
        }
        f
    }

    #[test]
    fn builds_within_budget() {
        let s = store();
        let f = freqs_for(&s);
        let cache = HotNeuronCache::build(&s, &f, 0.25, 1 << 20, false);
        assert!(cache.bytes() <= 1 << 20);
        assert!(cache.bytes() > 0);
    }

    #[test]
    fn caches_highest_frequency_rows() {
        let s = store();
        let f = freqs_for(&s);
        let cache = HotNeuronCache::build(&s, &f, 0.25, u64::MAX, false);
        let id = MatrixId::new(0, MatrixKind::Q);
        let rows = cache.cached_rows(id);
        assert!(!rows.is_empty());
        // Rows with freq 6/7 (i % 7 == 6) must be cached first.
        let freq = &f[&id];
        let min_cached = rows.iter().map(|&r| freq[r]).fold(1.0f64, f64::min);
        let max_uncached = (0..s.spec.d)
            .filter(|&r| !cache.is_cached(id, r))
            .map(|r| freq[r])
            .fold(0.0f64, f64::max);
        assert!(min_cached >= max_uncached);
    }

    #[test]
    fn members_share_mask() {
        let s = store();
        let f = freqs_for(&s);
        let cache = HotNeuronCache::build(&s, &f, 0.25, u64::MAX, false);
        let q = cache.cached_rows(MatrixId::new(0, MatrixKind::Q)).to_vec();
        let k = cache.cached_rows(MatrixId::new(0, MatrixKind::K)).to_vec();
        assert_eq!(q, k);
    }

    #[test]
    fn zero_cached_zeroes_only_cached() {
        let s = store();
        let f = freqs_for(&s);
        let cache = HotNeuronCache::build(&s, &f, 0.25, u64::MAX, false);
        let id = MatrixId::new(0, MatrixKind::Q);
        let mut imp = vec![1.0f32; s.spec.d];
        cache.zero_cached(id, &mut imp);
        for (r, &v) in imp.iter().enumerate() {
            assert_eq!(v == 0.0, cache.is_cached(id, r));
        }
    }

    #[test]
    fn subtract_cached_splits_chunks() {
        let s = store();
        let f = freqs_for(&s);
        let cache = HotNeuronCache::build(&s, &f, 0.25, u64::MAX, false);
        let id = MatrixId::new(0, MatrixKind::Q);
        let mut pieces = Vec::new();
        cache.subtract_cached_into(id, Chunk::new(0, s.spec.d), &mut pieces);
        // No piece contains a cached row; union covers all uncached rows.
        let mut covered = vec![false; s.spec.d];
        for p in &pieces {
            for r in p.start..p.end() {
                assert!(!cache.is_cached(id, r), "cached row {r} in flash piece");
                covered[r] = true;
            }
        }
        for r in 0..s.spec.d {
            assert_eq!(covered[r], !cache.is_cached(id, r));
        }
    }

    #[test]
    fn materialized_rows_match_store() {
        let s = store();
        let f = freqs_for(&s);
        let cache = HotNeuronCache::build(&s, &f, 0.2, u64::MAX, true);
        let id = MatrixId::new(1, MatrixKind::Down);
        let cols = s.spec.shape_of(MatrixKind::Down).cols;
        let logical = s.logical_matrix(id);
        for &r in cache.cached_rows(id) {
            let data = cache.row_data(id, r).unwrap();
            assert_eq!(data, &logical[r * cols..(r + 1) * cols]);
        }
    }

    #[test]
    fn over_budget_group_skipped_not_fatal() {
        let s = store();
        let f = freqs_for(&s);
        // At fraction 0.25 on tiny: the Q/K/V group costs 12288 B, O
        // 4096 B, Gate/Up 24576 B, Down 12288 B per layer. With a
        // 30000 B budget the Gate/Up group overflows — it must be
        // skipped while the *later* Down group still fills the budget
        // (the old `break 'outer` ended caching for every later group).
        let cache = HotNeuronCache::build(&s, &f, 0.25, 30_000, false);
        assert!(cache.bytes() <= 30_000);
        assert!(
            !cache.cached_rows(MatrixId::new(0, MatrixKind::Down)).is_empty(),
            "later group should still be cached after an over-budget skip"
        );
        assert!(cache.cached_rows(MatrixId::new(0, MatrixKind::Gate)).is_empty());
        assert_eq!(cache.bytes(), 28_672);
        // Group atomicity: members sharing a mask are cached together or
        // not at all (a partial group would break the engine's
        // subtract-cached flash-read logic).
        for layer in 0..s.spec.layers {
            for scored in MatrixKind::SCORED {
                for member in MatrixKind::ALL {
                    if member.mask_source() == scored {
                        assert_eq!(
                            cache.cached_rows(MatrixId::new(layer, member)),
                            cache.cached_rows(MatrixId::new(layer, scored)),
                            "partial group at layer {layer} {scored:?}/{member:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_budget_caches_nothing() {
        let s = store();
        let f = freqs_for(&s);
        let cache = HotNeuronCache::build(&s, &f, 0.25, 0, false);
        assert_eq!(cache.bytes(), 0);
    }
}
