//! `repro-figures` — regenerate every table and figure of the paper.
//!
//! Usage:
//!   repro-figures [--quick|--full] [--out DIR] <target>...
//!   targets: fig2 fig3 fig4a fig4b fig5 fig6 fig7 fig8 fig9 fig10 fig11
//!            fig12 fig13 fig16 table1 table2 table3 appn devices all
//!
//! Each target prints its tables and writes `reports/<target>_<n>.csv`.

use std::path::PathBuf;

use neuron_chunking::experiments as exp;
use neuron_chunking::report::Table;
use neuron_chunking::storage::DeviceProfile;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut quality = exp::Quality::full();
    let mut out_dir = PathBuf::from(".");
    let mut targets: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => quality = exp::Quality::quick(),
            "--full" => quality = exp::Quality::full(),
            "--out" => {
                i += 1;
                out_dir = PathBuf::from(&args[i]);
            }
            "-h" | "--help" => {
                print_help();
                return;
            }
            t => targets.push(t.to_string()),
        }
        i += 1;
    }
    if targets.is_empty() {
        print_help();
        std::process::exit(2);
    }
    if targets.iter().any(|t| t == "all") {
        targets = ALL.iter().map(|s| s.to_string()).collect();
    }
    let artifact_dir = std::env::var("NC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));

    let mut failures = 0;
    for target in &targets {
        let t0 = std::time::Instant::now();
        eprintln!("--- running {target} ---");
        let result: anyhow::Result<Vec<Table>> = match target.as_str() {
            "fig2" => exp::fig2(quality),
            "fig3" => exp::fig3(quality),
            "fig4a" => exp::fig4a(quality),
            "fig4b" => exp::fig4b(quality),
            "fig5" => exp::fig5(quality),
            "fig6" => exp::fig6(DeviceProfile::nano(), quality),
            "fig6real" => exp::fig6_real(&artifact_dir, quality),
            "fig7" | "fig14" => exp::fig6(DeviceProfile::agx(), quality),
            "fig8" => exp::fig8(&artifact_dir, quality),
            "fig9" => exp::fig9(quality),
            "fig10" | "fig15" => exp::fig10(quality),
            "fig11" => exp::fig11(quality),
            "fig12" => exp::fig12(quality),
            "fig13" => exp::fig13(quality),
            "fig16" => exp::fig16(quality),
            "table1" => exp::table1(quality),
            "table2" => exp::table2(quality),
            "table3" => exp::table3(quality),
            "appn" => exp::appn(quality),
            "iouring" => exp::disc_iouring(quality),
            "devices" => exp::devices(quality),
            other => {
                eprintln!("unknown target: {other}");
                failures += 1;
                continue;
            }
        };
        match result {
            Ok(tables) => {
                for (i, t) in tables.iter().enumerate() {
                    println!("{}", t.render());
                    let name = if tables.len() == 1 {
                        target.clone()
                    } else {
                        format!("{target}_{i}")
                    };
                    match t.write_csv(&out_dir, &name) {
                        Ok(p) => eprintln!("  wrote {}", p.display()),
                        Err(e) => eprintln!("  csv write failed: {e}"),
                    }
                }
                eprintln!("--- {target} done in {:.1}s ---\n", t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                eprintln!("{target} FAILED: {e:#}");
                failures += 1;
            }
        }
    }
    if failures > 0 {
        std::process::exit(1);
    }
}

const ALL: &[&str] = &[
    "devices", "fig2", "fig3", "fig4a", "fig4b", "fig5", "fig6", "fig6real", "fig7",
    "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig16", "table1", "table2",
    "table3", "appn", "iouring",
];

fn print_help() {
    eprintln!(
        "repro-figures — regenerate the paper's tables and figures\n\
         usage: repro-figures [--quick|--full] [--out DIR] <target>...\n\
         targets: {} all",
        ALL.join(" ")
    );
}
