//! `redline` — the serving load harness.
//!
//! ```text
//! redline run     --addr HOST:PORT [--rps R] [--duration S] [--streams N]
//!                 [--connections C] [--mix P:D] [--steps K] [--burst B]
//!                 [--out FILE]
//! redline compare BASELINE.json CANDIDATE.json [--pct N]
//! ```
//!
//! `run` drives a live `repro serve` instance open-loop at the target
//! RPS, prints a latency/throughput table, and writes a JSON run file
//! (default `BENCH_serving.json`) whose entries the CI bench gate
//! consumes directly. `compare` diffs two run files and exits 1 when any
//! matched entry regressed past the threshold (default 10%) — the same
//! verdict rules the gate applies, so a clean local compare means a
//! clean CI gate.

use std::process::ExitCode;
use std::time::Duration;

use neuron_chunking::serving::args::{parse_mix, slo_from_args, ArgError, ArgParser};
use neuron_chunking::serving::loadgen::{self, compare_files, RunConfig};

const USAGE: &str = "usage:
  redline run     --addr HOST:PORT [--rps R] [--duration S] [--streams N]
                  [--connections C] [--mix P:D] [--steps K] [--burst B]
                  [--slo-ms MS] [--out FILE]
  redline compare BASELINE.json CANDIDATE.json [--pct N]

  --mix P:D    prefill:decode requests per cycle (validated; 0:0 rejected)
  --slo-ms MS  stamp decode deadlines of MS ms (typed API; 0 = none) and
               record \"slo\" in the run identity";

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let result = match argv.first().map(String::as_str) {
        Some("run") => cmd_run(&argv[1..]),
        Some("compare") => cmd_compare(&argv[1..]),
        _ => {
            eprintln!("{USAGE}");
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(code) => code,
        Err(e) => {
            eprintln!("redline: {e}");
            eprintln!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn cmd_run(args: &[String]) -> Result<ExitCode, ArgError> {
    let p = ArgParser::new(args);
    let mix = match p.raw("--mix")? {
        Some(s) => parse_mix(s)?,
        None => (1, 8),
    };
    let duration_s: f64 = p.parsed_or("--duration", 10.0)?;
    let cfg = RunConfig {
        addr: p.string_or("--addr", "127.0.0.1:8321")?,
        rps: p.parsed_or("--rps", 20.0)?,
        burst: p.parsed_or("--burst", 4usize)?,
        duration: Duration::from_secs_f64(duration_s.max(0.1)),
        streams: p.parsed_or("--streams", 4usize)?,
        connections: p.parsed_or("--connections", 4usize)?,
        mix,
        steps: p.parsed_or("--steps", 4usize)?,
        deadline_ms: slo_from_args(&p)?.map(|d| d.as_millis() as u64),
    };
    let out_path = p.string_or("--out", "BENCH_serving.json")?;

    let report = match loadgen::run(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("redline run failed: {e}");
            return Ok(ExitCode::FAILURE);
        }
    };
    print!("{}", report.render_table());
    if let Err(e) = std::fs::write(&out_path, report.to_json()) {
        eprintln!("cannot write {out_path}: {e}");
        return Ok(ExitCode::FAILURE);
    }
    println!("run file written to {out_path}");
    let requests = report.decode.requests + report.append.requests;
    let errors = report.decode.errors + report.append.errors;
    if requests == 0 || errors == requests {
        eprintln!("redline: no successful requests ({errors}/{requests} errored)");
        return Ok(ExitCode::FAILURE);
    }
    Ok(ExitCode::SUCCESS)
}

fn cmd_compare(args: &[String]) -> Result<ExitCode, ArgError> {
    let p = ArgParser::new(args);
    let pct: f64 = p.parsed_or("--pct", 10.0)?;
    let positional: Vec<&String> = args
        .iter()
        .enumerate()
        .filter(|(i, a)| {
            !a.starts_with("--") && (*i == 0 || args[i - 1] != "--pct")
        })
        .map(|(_, a)| a)
        .collect();
    let [baseline_path, candidate_path] = positional.as_slice() else {
        return Err(ArgError {
            flag: "compare".to_string(),
            reason: "needs exactly two run files".to_string(),
        });
    };
    let read = |path: &str| {
        std::fs::read_to_string(path).map_err(|e| ArgError {
            flag: path.to_string(),
            reason: format!("cannot read: {e}"),
        })
    };
    let baseline = read(baseline_path)?;
    let candidate = read(candidate_path)?;
    match compare_files(&baseline, &candidate, pct) {
        Ok(report) => {
            print!("{}", report.render());
            if report.regressions() > 0 {
                eprintln!("redline compare: REGRESSED vs {baseline_path}");
                Ok(ExitCode::FAILURE)
            } else {
                Ok(ExitCode::SUCCESS)
            }
        }
        Err(e) => {
            eprintln!("redline compare failed: {e}");
            Ok(ExitCode::FAILURE)
        }
    }
}
