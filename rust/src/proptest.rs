//! Minimal property-testing harness (the `proptest` crate is unavailable
//! offline). Runs a property over many deterministic random cases and
//! reports the failing seed so cases can be replayed exactly.
//!
//! ```
//! use neuron_chunking::proptest::check;
//! check("sum is commutative", 200, |rng| {
//!     let (a, b) = (rng.below(100), rng.below(100));
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use crate::rng::Rng;

/// Run `prop` over `cases` deterministic seeds; panic with the seed and
/// message on the first failure.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_seeded(name, cases, 0xC0FFEE, prop)
}

/// Like [`check`] with an explicit base seed (replay a failure by passing
/// the reported seed with `cases = 1`).
pub fn check_seeded<F>(name: &str, cases: u64, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Generate a random importance vector with mixed structure (uniform,
/// spiky, clustered, constant) — the adversarial input family for
/// selection properties.
pub fn arb_importance(rng: &mut Rng, max_n: usize) -> Vec<f32> {
    let n = rng.range(1, max_n.max(2));
    let style = rng.below(4);
    (0..n)
        .map(|i| match style {
            0 => rng.f32(),                                     // uniform
            1 => rng.f32().powi(6),                             // spiky
            2 => ((i / 8) % 2) as f32 + 0.01 * rng.f32(),       // clustered
            _ => 1.0,                                           // constant
        })
        .collect()
}

/// A random (but valid) latency table with positive, non-decreasing
/// entries.
pub fn arb_latency_table(rng: &mut Rng) -> crate::latency::LatencyTable {
    let steps = rng.range(4, 64);
    let base = 10e-6 * (1.0 + rng.f64() * 20.0);
    let slope = 0.1e-6 * (1.0 + rng.f64() * 10.0);
    let entries: Vec<f64> = (1..=steps)
        .map(|i| base + slope * i as f64 * (1.0 + 0.1 * rng.f64()))
        .scan(0.0f64, |acc, v| {
            *acc = acc.max(v);
            Some(*acc)
        })
        .collect();
    let row_bytes = [256usize, 1024, 4096][rng.below(3)];
    crate::latency::LatencyTable::new(1024, entries, row_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0u64);
        check("always ok", 50, |_| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            if rng.below(3) < 2 {
                Ok(())
            } else {
                Err("boom".into())
            }
        });
    }

    #[test]
    fn deterministic_replay() {
        // Same base seed -> same generated values.
        let mut first = Vec::new();
        check_seeded("gen", 5, 42, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        check_seeded("gen", 5, 42, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn arb_importance_valid() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = arb_importance(&mut rng, 256);
            assert!(!v.is_empty() && v.len() <= 256);
            assert!(v.iter().all(|x| x.is_finite() && *x >= 0.0));
        }
    }

    #[test]
    fn arb_table_valid() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let t = arb_latency_table(&mut rng);
            assert!(t.latency_bytes(1024) > 0.0);
            // Non-decreasing.
            assert!(t.latency_bytes(4096) <= t.latency_bytes(8192) + 1e-15);
        }
    }
}
