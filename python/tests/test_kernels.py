"""Kernel-vs-oracle correctness: the core signal for the compile path.

Each Pallas kernel (interpret=True) is checked against its pure-jnp
reference in ref.py, both on fixed cases and hypothesis-driven shape/value
sweeps.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import mha_attention
from compile.kernels.gated_mlp import fused_gateup
from compile.kernels.sparse_matmul import gathered_matmul, _pick_k_tile

RNG = np.random.default_rng(1234)


def randf(*shape, scale=1.0):
    return jnp.asarray(
        RNG.standard_normal(shape).astype(np.float32) * scale
    )


# ---------------------------------------------------------------- tiling


@pytest.mark.parametrize(
    "r,expect",
    [(16, 16), (48, 16), (64, 64), (128, 128), (192, 64), (768, 128), (1, 1), (6, 2)],
)
def test_pick_k_tile(r, expect):
    kt = _pick_k_tile(r)
    assert kt == expect
    assert r % kt == 0


def test_pick_k_tile_always_divides():
    for r in range(1, 512):
        assert r % _pick_k_tile(r) == 0


# -------------------------------------------------------- gathered matmul


@pytest.mark.parametrize("t", [1, 8, 16])
@pytest.mark.parametrize("r", [16, 48, 192, 256])
@pytest.mark.parametrize("n", [64, 192])
def test_gathered_matmul_matches_ref(t, r, n):
    xs, w = randf(t, r), randf(r, n)
    np.testing.assert_allclose(
        gathered_matmul(xs, w), ref.gathered_matmul(xs, w), atol=1e-4, rtol=1e-4
    )


def test_gathered_matmul_zero_row_padding_exact():
    """Budget-bucket padding: appended zero rows change nothing."""
    t, r, n, pad = 4, 32, 64, 16
    xs, w = randf(t, r), randf(r, n)
    xs_p = jnp.concatenate([xs, jnp.zeros((t, pad), jnp.float32)], axis=1)
    w_p = jnp.concatenate([w, jnp.zeros((pad, n), jnp.float32)], axis=0)
    np.testing.assert_allclose(
        gathered_matmul(xs_p, w_p), gathered_matmul(xs, w), atol=1e-5
    )


def test_gathered_matmul_identity():
    xs = randf(8, 64)
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(gathered_matmul(xs, eye), xs, atol=1e-5)


def test_gathered_matmul_explicit_k_tile():
    xs, w = randf(4, 96), randf(96, 32)
    for kt in (16, 32, 48, 96):
        np.testing.assert_allclose(
            gathered_matmul(xs, w, k_tile=kt),
            ref.gathered_matmul(xs, w),
            atol=1e-4,
        )


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 12),
    rk=st.integers(1, 12),
    n=st.integers(1, 48),
    scale=st.floats(0.01, 10.0),
)
def test_gathered_matmul_hypothesis(t, rk, n, scale):
    r = rk * 16
    rng = np.random.default_rng(t * 1000 + rk * 100 + n)
    xs = jnp.asarray(rng.standard_normal((t, r)).astype(np.float32) * scale)
    w = jnp.asarray(rng.standard_normal((r, n)).astype(np.float32))
    np.testing.assert_allclose(
        gathered_matmul(xs, w), ref.gathered_matmul(xs, w), atol=1e-3, rtol=1e-3
    )


# ------------------------------------------------------------ fused gateup


@pytest.mark.parametrize("t", [1, 8])
@pytest.mark.parametrize("r", [16, 48, 64])
@pytest.mark.parametrize("h", [48, 192])
def test_fused_gateup_matches_ref(t, r, h):
    xs, wg, wu = randf(t, r), randf(r, h), randf(r, h)
    np.testing.assert_allclose(
        fused_gateup(xs, wg, wu), ref.fused_gateup(xs, wg, wu), atol=1e-4, rtol=1e-4
    )


def test_fused_gateup_zero_padding_exact():
    t, r, h, pad = 4, 32, 96, 32
    xs, wg, wu = randf(t, r), randf(r, h), randf(r, h)
    xs_p = jnp.concatenate([xs, jnp.zeros((t, pad), jnp.float32)], axis=1)
    wg_p = jnp.concatenate([wg, jnp.zeros((pad, h), jnp.float32)], axis=0)
    wu_p = jnp.concatenate([wu, jnp.zeros((pad, h), jnp.float32)], axis=0)
    np.testing.assert_allclose(
        fused_gateup(xs_p, wg_p, wu_p), fused_gateup(xs, wg, wu), atol=1e-5
    )


def test_fused_gateup_silu_negative_gate():
    """silu keeps negative-gate contributions small but nonzero."""
    xs = jnp.ones((1, 16), jnp.float32)
    wg = -jnp.ones((16, 8), jnp.float32)  # gate = -16
    wu = jnp.ones((16, 8), jnp.float32)  # up = 16
    out = np.asarray(fused_gateup(xs, wg, wu))
    expected = (-16.0 / (1.0 + np.exp(16.0))) * 16.0
    np.testing.assert_allclose(out, expected, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(t=st.integers(1, 8), rk=st.integers(1, 8), h=st.integers(1, 64))
def test_fused_gateup_hypothesis(t, rk, h):
    r = rk * 16
    rng = np.random.default_rng(t * 997 + rk * 31 + h)
    xs = jnp.asarray(rng.standard_normal((t, r)).astype(np.float32))
    wg = jnp.asarray(rng.standard_normal((r, h)).astype(np.float32) * 0.5)
    wu = jnp.asarray(rng.standard_normal((r, h)).astype(np.float32) * 0.5)
    np.testing.assert_allclose(
        fused_gateup(xs, wg, wu), ref.fused_gateup(xs, wg, wu), atol=1e-3, rtol=1e-3
    )


# --------------------------------------------------------------- attention


@pytest.mark.parametrize("t", [1, 8])
@pytest.mark.parametrize("s", [8, 40])
@pytest.mark.parametrize("nh", [1, 4])
def test_mha_matches_ref(t, s, nh):
    d = 16 * nh
    q, k, v = randf(t, d), randf(s, d), randf(s, d)
    mask = jnp.asarray((RNG.random(s) > 0.3).astype(np.float32))
    np.testing.assert_allclose(
        mha_attention(q, k, v, mask, nh),
        ref.mha_attention(q, k, v, mask, nh),
        atol=1e-4,
        rtol=1e-4,
    )


def test_mha_all_valid_mask_uniform_values():
    """With identical values on every slot, output must equal that value."""
    t, s, nh, d = 2, 10, 2, 32
    q, k = randf(t, d), randf(s, d)
    v = jnp.ones((s, d), jnp.float32) * 3.5
    mask = jnp.ones((s,), jnp.float32)
    np.testing.assert_allclose(
        mha_attention(q, k, v, mask, nh), 3.5, rtol=1e-5
    )


def test_mha_masked_slots_ignored():
    """Garbage in masked slots must not leak into the output."""
    t, s, nh, d = 2, 12, 2, 32
    q, k, v = randf(t, d), randf(s, d), randf(s, d)
    mask = jnp.asarray(([1.0] * 6) + ([0.0] * 6), jnp.float32)
    out1 = mha_attention(q, k, v, mask, nh)
    k2 = k.at[6:].set(1e3)
    v2 = v.at[6:].set(-1e3)
    out2 = mha_attention(q, k2, v2, mask, nh)
    np.testing.assert_allclose(out1, out2, atol=1e-3)


def test_mha_probs_convexity():
    """Output lies inside the convex hull of valid value rows."""
    t, s, nh, d = 4, 16, 4, 64
    q, k, v = randf(t, d), randf(s, d), randf(s, d)
    mask = jnp.ones((s,), jnp.float32)
    out = np.asarray(mha_attention(q, k, v, mask, nh))
    vh = np.asarray(v).reshape(s, nh, d // nh)
    for h in range(nh):
        lo, hi = vh[:, h].min(axis=0), vh[:, h].max(axis=0)
        oh = out.reshape(t, nh, d // nh)[:, h]
        assert (oh >= lo - 1e-4).all() and (oh <= hi + 1e-4).all()


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(1, 6),
    s=st.integers(2, 24),
    nh=st.sampled_from([1, 2, 4]),
    valid=st.integers(1, 24),
)
def test_mha_hypothesis(t, s, nh, valid):
    d = 8 * nh
    rng = np.random.default_rng(t * 7919 + s * 131 + nh)
    q = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((s, d)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((s, d)).astype(np.float32))
    mask = np.zeros(s, np.float32)
    mask[: min(valid, s)] = 1.0
    mask = jnp.asarray(mask)
    np.testing.assert_allclose(
        mha_attention(q, k, v, mask, nh),
        ref.mha_attention(q, k, v, mask, nh),
        atol=1e-3,
        rtol=1e-3,
    )


# ----------------------------------------------------------------- rmsnorm


def test_rmsnorm_unit_rms():
    x = randf(6, 64, scale=5.0)
    out = np.asarray(ref.rmsnorm(x))
    rms = np.sqrt((out**2).mean(axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_rmsnorm_scale_invariant_direction():
    x = randf(2, 32)
    a = np.asarray(ref.rmsnorm(x))
    b = np.asarray(ref.rmsnorm(x * 100.0))
    np.testing.assert_allclose(a, b, atol=1e-4)
