"""AOT pipeline tests: lowering produces parseable HLO text with the right
entry signature, and the manifest is consistent with the model specs."""

import json
import os
import re

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tiny_build(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, ["tiny"], verbose=False)
    return out, manifest


def test_manifest_counts(tiny_build):
    out, manifest = tiny_build
    dims = model.TINY
    n_qkv = 2 * len(dims.d_buckets)
    n_gateup = 2 * len(dims.d_buckets)
    n_proj = 2 * len(set(dims.d_buckets) | set(dims.h_buckets))
    assert len(manifest["artifacts"]) == n_qkv + n_gateup + n_proj
    # + manifest.json + manifest.tsv
    assert len(os.listdir(out)) == len(manifest["artifacts"]) + 2


def test_manifest_matches_files(tiny_build):
    out, manifest = tiny_build
    disk = json.load(open(os.path.join(out, "manifest.json")))
    assert disk == manifest
    for art in manifest["artifacts"]:
        path = os.path.join(out, art["file"])
        assert os.path.exists(path), art["file"]
        text = open(path).read()
        assert text.startswith("HloModule"), art["file"]
        assert "ENTRY" in text


def test_entry_layout_matches_manifest_shapes(tiny_build):
    out, manifest = tiny_build
    for art in manifest["artifacts"][:8]:
        text = open(os.path.join(out, art["file"])).read()
        m = re.search(r"entry_computation_layout=\{\((.*?)\)->", text)
        assert m, art["file"]
        params = re.findall(r"f32\[([\d,]*)\]", m.group(1))
        got = [
            [int(x) for x in p.split(",")] if p else [] for p in params
        ]
        assert got == art["inputs"], art["name"]


def test_hlo_deterministic(tmp_path):
    """Same spec lowers to byte-identical HLO (stable sha in manifest)."""
    dims = model.TINY
    spec = model.artifact_specs(dims)[0]
    a = aot.lower_spec(spec)
    b = aot.lower_spec(spec)
    assert a == b


def test_output_tuple_arity(tiny_build):
    out, manifest = tiny_build
    for art in manifest["artifacts"]:
        text = open(os.path.join(out, art["file"])).read()
        m = re.search(r"->\((.*?)\)\}", text)
        assert m, art["name"]
        arity = len(re.findall(r"f32\[", m.group(1)))
        assert arity == art["outputs"], art["name"]


def test_models_in_manifest(tiny_build):
    _, manifest = tiny_build
    assert manifest["models"]["tiny"]["d"] == model.TINY.d
    assert manifest["models"]["tiny"]["layers"] == model.TINY.layers
    assert manifest["models"]["tiny"]["d_buckets"] == model.TINY.d_buckets
