"""L2 stage-function tests: shapes, composition against a dense block, and
the sparsification contract (gathered rows == masked-input computation)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def randf(rng, *shape, scale=0.3):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


def dense_block_ref(x, wq, wk, wv, wo, wg, wu, wd, kc, vc, mask, nh):
    """Full (unsparsified) transformer block, the accuracy gold standard."""
    hn = ref.rmsnorm(x)
    attn, k, v = ref.qkv_attn_append(hn, wq, wk, wv, kc, vc, mask, nh)
    x1 = x + np.asarray(ref.gathered_matmul(attn, wo))
    h2 = ref.rmsnorm(x1)
    act = ref.fused_gateup(h2, wg, wu)
    y = x1 + np.asarray(ref.gathered_matmul(act, wd))
    return np.asarray(y), np.asarray(k), np.asarray(v)


class TestModelDims:
    def test_buckets_multiple_of_16(self):
        for m in model.MODELS.values():
            for b in m.d_buckets + m.h_buckets:
                assert b % 16 == 0
                assert 16 <= b

    def test_buckets_descending_unique(self):
        for m in model.MODELS.values():
            for bs in (m.d_buckets, m.h_buckets):
                assert bs == sorted(set(bs), reverse=True)

    def test_full_bucket_present(self):
        for m in model.MODELS.values():
            assert m.d_buckets[0] == m.d
            assert m.h_buckets[0] == m.h

    def test_head_divides_hidden(self):
        for m in model.MODELS.values():
            assert m.d % m.nh == 0


class TestArtifactSpecs:
    @pytest.mark.parametrize("name", ["tiny", "small"])
    def test_spec_inventory(self, name):
        dims = model.MODELS[name]
        specs = model.artifact_specs(dims)
        kinds = {}
        for s in specs:
            kinds.setdefault(s["kind"], []).append(s["r"])
        # qkv/gateup per d-bucket; projres per union bucket.
        assert sorted(kinds["qkv_append"], reverse=True) == dims.d_buckets
        assert sorted(kinds["qkv_decode"], reverse=True) == dims.d_buckets
        assert sorted(kinds["gateup"], reverse=True) == dims.d_buckets
        union = sorted(set(dims.d_buckets) | set(dims.h_buckets))
        assert sorted(kinds["projres"]) == union
        assert sorted(kinds["projres_dec"]) == union

    def test_spec_names_unique(self):
        for dims in (model.TINY, model.SMALL):
            names = [s["name"] for s in model.artifact_specs(dims)]
            assert len(names) == len(set(names))

    def test_spec_arg_shapes_consistent(self):
        dims = model.TINY
        for s in model.artifact_specs(dims):
            if s["kind"].startswith("qkv"):
                t, r = s["args"][0].shape
                assert r == s["r"] and t == s["t"]
                assert s["args"][1].shape == (r, dims.d)
                assert s["args"][4].shape == (dims.c, dims.d)


class TestStageFunctions:
    def test_qkv_attn_matches_ref(self):
        dims = model.TINY
        rng = np.random.default_rng(7)
        r = dims.d
        xs = randf(rng, dims.t, r)
        wq, wk, wv = (randf(rng, r, dims.d) for _ in range(3))
        kc, vc = randf(rng, dims.c, dims.d), randf(rng, dims.c, dims.d)
        mask = jnp.zeros((dims.c,), jnp.float32).at[:10].set(1.0)
        fn = model.make_qkv_attn(dims, dims.t)
        attn, k, v = fn(xs, wq, wk, wv, kc, vc, mask)
        ra, rk_, rv = ref.qkv_attn_append(xs, wq, wk, wv, kc, vc, mask, dims.nh)
        np.testing.assert_allclose(attn, ra, atol=1e-4)
        np.testing.assert_allclose(k, rk_, atol=1e-4)
        np.testing.assert_allclose(v, rv, atol=1e-4)

    def test_proj_residual_matches_ref(self):
        rng = np.random.default_rng(8)
        a, w, res = randf(rng, 8, 48), randf(rng, 48, 64), randf(rng, 8, 64)
        (out,) = model.proj_residual(a, w, res)
        np.testing.assert_allclose(
            out, ref.proj_residual(a, w, res), atol=1e-4
        )

    def test_gateup_matches_ref(self):
        rng = np.random.default_rng(9)
        xs, wg, wu = randf(rng, 8, 32), randf(rng, 32, 96), randf(rng, 32, 96)
        (out,) = model.gateup(xs, wg, wu)
        np.testing.assert_allclose(out, ref.fused_gateup(xs, wg, wu), atol=1e-4)


class TestSparsificationContract:
    """Gathered-row computation must equal masked-input computation — the
    invariant the whole Rust gather pipeline relies on."""

    def test_gather_equals_mask_matmul(self):
        rng = np.random.default_rng(10)
        n, out_d, t = 64, 32, 4
        a = randf(rng, t, n)
        w = randf(rng, n, out_d)
        sel = np.sort(rng.choice(n, size=24, replace=False))
        dense_masked = np.asarray(a).copy()
        keep = np.zeros(n, bool)
        keep[sel] = True
        dense_masked[:, ~keep] = 0.0
        y_mask = dense_masked @ np.asarray(w)
        y_gather = np.asarray(
            ref.gathered_matmul(
                jnp.asarray(np.asarray(a)[:, sel]), jnp.asarray(np.asarray(w)[sel])
            )
        )
        np.testing.assert_allclose(y_gather, y_mask, atol=1e-4)

    def test_full_budget_block_equals_dense(self):
        """Composing the three stages at full budget reproduces the dense
        block bit-for-bit (up to float tolerance)."""
        dims = model.TINY
        rng = np.random.default_rng(11)
        x = randf(rng, dims.t, dims.d, scale=0.5)
        wq, wk, wv, wo = (randf(rng, dims.d, dims.d) for _ in range(4))
        wg, wu = randf(rng, dims.d, dims.h), randf(rng, dims.d, dims.h)
        wd = randf(rng, dims.h, dims.d)
        kc = randf(rng, dims.c, dims.d)
        vc = randf(rng, dims.c, dims.d)
        mask = jnp.zeros((dims.c,), jnp.float32).at[:5].set(1.0)

        # staged pipeline at full budget (identity gather)
        hn = ref.rmsnorm(x)
        attn, k, v = model.make_qkv_attn(dims, dims.t)(
            hn, wq, wk, wv, kc, vc, mask
        )
        (x1,) = model.proj_residual(attn, wo, x)
        h2 = ref.rmsnorm(x1)
        (act,) = model.gateup(h2, wg, wu)
        (y,) = model.proj_residual(act, wd, x1)

        gy, gk, gv = dense_block_ref(
            x, wq, wk, wv, wo, wg, wu, wd, kc, vc, mask, dims.nh
        )
        np.testing.assert_allclose(np.asarray(y), gy, atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(k), gk, atol=1e-4)
        np.testing.assert_allclose(np.asarray(v), gv, atol=1e-4)

    def test_sparsified_block_bounded_error(self):
        """Dropping the lowest-|a| rows produces small, bounded output error
        (sanity for the accuracy-proxy methodology)."""
        dims = model.TINY
        rng = np.random.default_rng(12)
        t, n, out_d = dims.t, dims.d, dims.d
        a = randf(rng, t, n, scale=1.0)
        w = randf(rng, n, out_d, scale=0.2)
        imp = np.abs(np.asarray(a)).mean(axis=0)
        order = np.argsort(-imp)
        dense = np.asarray(a) @ np.asarray(w)
        prev_err = None
        for keep in (n, 3 * n // 4, n // 2):
            sel = np.sort(order[:keep])
            y = np.asarray(a)[:, sel] @ np.asarray(w)[sel]
            err = np.abs(y - dense).mean()
            if prev_err is not None:
                assert err >= prev_err - 1e-5  # error grows as budget shrinks
            prev_err = err
        assert prev_err < np.abs(dense).mean()  # still far from garbage
