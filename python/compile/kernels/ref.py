"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float addition
order) counterpart here. `python/tests/` asserts allclose between the two
across shape/dtype sweeps; this is the core correctness signal for the
compile path.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e9  # large-negative mask value (not -inf: keeps softmax finite)


def gathered_matmul(xs: jax.Array, w: jax.Array) -> jax.Array:
    """y = xs @ w, where xs is [T, R] gathered activations and w is [R, N]
    gathered weight rows. Plain matmul; the gather happened upstream (in the
    Rust coordinator, after chunk selection)."""
    return jnp.dot(xs, w, preferred_element_type=jnp.float32)


def fused_gateup(xs: jax.Array, wg: jax.Array, wu: jax.Array) -> jax.Array:
    """SwiGLU gate/up over gathered rows: act = silu(xs@wg) * (xs@wu).

    xs: [T, R]; wg, wu: [R, H]; returns [T, H].
    Zero-padded rows of xs/wg/wu contribute exactly zero, so budget-bucket
    padding is lossless.
    """
    gate = jnp.dot(xs, wg, preferred_element_type=jnp.float32)
    up = jnp.dot(xs, wu, preferred_element_type=jnp.float32)
    return jax.nn.silu(gate) * up


def mha_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array, num_heads: int
) -> jax.Array:
    """Multi-head attention of T query tokens over S key/value slots.

    q: [T, nh*hd]; k, v: [S, nh*hd]; mask: [S] with 1.0 = valid slot.
    Returns [T, nh*hd]. Masked slots receive NEG_INF pre-softmax.
    """
    t, d = q.shape
    s = k.shape[0]
    hd = d // num_heads
    qh = q.reshape(t, num_heads, hd).transpose(1, 0, 2)  # [nh, T, hd]
    kh = k.reshape(s, num_heads, hd).transpose(1, 0, 2)  # [nh, S, hd]
    vh = v.reshape(s, num_heads, hd).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", qh, kh) / jnp.sqrt(jnp.float32(hd))
    scores = scores + (1.0 - mask)[None, None, :] * NEG_INF
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", probs, vh)  # [nh, T, hd]
    return out.transpose(1, 0, 2).reshape(t, d)


def proj_residual(a_sel: jax.Array, w: jax.Array, res: jax.Array) -> jax.Array:
    """Output projection over gathered rows plus residual: res + a_sel @ w."""
    return res + jnp.dot(a_sel, w, preferred_element_type=jnp.float32)


def rmsnorm(x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Scale-free RMSNorm (matches the Rust-side host implementation)."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps)


def qkv_attn_append(
    xs: jax.Array,
    wq: jax.Array,
    wk: jax.Array,
    wv: jax.Array,
    kc: jax.Array,
    vc: jax.Array,
    mask: jax.Array,
    num_heads: int,
):
    """Reference for the fused qkv+attention append stage.

    xs: [T, R] gathered (post-norm) activations; wq/wk/wv: [R, d] gathered
    rows; kc/vc: [C, d] KV cache; mask: [C]. Frame tokens attend over all
    valid cache slots plus the whole current frame (non-causal within the
    frame, matching vision-token semantics).
    Returns (attn_out [T, d], k_new [T, d], v_new [T, d]).
    """
    q = gathered_matmul(xs, wq)
    k = gathered_matmul(xs, wk)
    v = gathered_matmul(xs, wv)
    keys = jnp.concatenate([kc, k], axis=0)
    vals = jnp.concatenate([vc, v], axis=0)
    full_mask = jnp.concatenate([mask, jnp.ones((xs.shape[0],), mask.dtype)])
    attn = mha_attention(q, keys, vals, full_mask, num_heads)
    return attn, k, v
