"""Pallas gathered-matmul kernel — the compute half of neuron chunking.

The Rust coordinator selects neuron chunks, reads their weight rows from
flash, and hands this kernel a *gathered* pair (xs [T, R], w [R, N]) where
R is the selection budget bucket. The kernel computes y = xs @ w by tiling
the contraction (R) dimension.

TPU mapping (see DESIGN.md §Hardware-Adaptation): each grid step stages one
[T, kt] activation tile and one [kt, N] weight tile into VMEM via BlockSpec
and accumulates a [T, N] f32 partial on the MXU. The contiguous chunk reads
the paper performs from flash become contiguous HBM->VMEM tiles here.

Runs under interpret=True so the lowered HLO executes on the CPU PJRT
client (real-TPU lowering emits Mosaic custom-calls the CPU plugin cannot
run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_k_tile(r: int, max_tile: int = 128) -> int:
    """Largest power-of-two tile <= max_tile that divides the contraction
    dim. Budget buckets are multiples of 16, so this is >= 16 in practice."""
    kt = 1
    t = 1
    while t <= max_tile and r % t == 0:
        kt = t
        t *= 2
    return kt


def _gathered_matmul_kernel(xs_ref, w_ref, o_ref):
    """Grid: (R // kt,). Accumulates partial products into the revisited
    output block (constant index map), the standard Pallas k-loop pattern."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        xs_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("k_tile",))
def gathered_matmul(xs: jax.Array, w: jax.Array, k_tile: int | None = None):
    """y = xs @ w over gathered rows. xs: [T, R]; w: [R, N] -> [T, N]."""
    t, r = xs.shape
    r2, n = w.shape
    assert r == r2, f"contraction mismatch {r} vs {r2}"
    kt = k_tile or _pick_k_tile(r)
    assert r % kt == 0
    return pl.pallas_call(
        _gathered_matmul_kernel,
        grid=(r // kt,),
        in_specs=[
            pl.BlockSpec((t, kt), lambda i: (0, i)),
            pl.BlockSpec((kt, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((t, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, n), jnp.float32),
        interpret=True,
    )(xs, w)
