"""Fused SwiGLU gate/up Pallas kernel over gathered neuron rows.

act = silu(xs @ wg) * (xs @ wu)

Both contractions share the same gathered activation tile, so fusing them
halves the activation traffic versus two separate matmuls. Gate and up
partials accumulate in VMEM scratch across the k-grid; the SwiGLU epilogue
runs once on the final grid step.

Zero-padded rows (budget-bucket padding) are exact: a zero row contributes
zero to both partial sums, and silu/multiply happen only after the full
reduction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .sparse_matmul import _pick_k_tile


def _fused_gateup_kernel(xs_ref, wg_ref, wu_ref, o_ref, g_acc, u_acc):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)
        u_acc[...] = jnp.zeros_like(u_acc)

    xs = xs_ref[...]
    g_acc[...] += jnp.dot(xs, wg_ref[...], preferred_element_type=jnp.float32)
    u_acc[...] += jnp.dot(xs, wu_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == pl.num_programs(0) - 1)
    def _epilogue():
        g = g_acc[...]
        o_ref[...] = (g * jax.nn.sigmoid(g)) * u_acc[...]


@functools.partial(jax.jit, static_argnames=("k_tile",))
def fused_gateup(
    xs: jax.Array, wg: jax.Array, wu: jax.Array, k_tile: int | None = None
):
    """act = silu(xs@wg) * (xs@wu). xs: [T, R]; wg, wu: [R, H] -> [T, H]."""
    t, r = xs.shape
    rg, h = wg.shape
    assert wg.shape == wu.shape and r == rg
    kt = k_tile or _pick_k_tile(r)
    assert r % kt == 0
    return pl.pallas_call(
        _fused_gateup_kernel,
        grid=(r // kt,),
        in_specs=[
            pl.BlockSpec((t, kt), lambda i: (0, i)),
            pl.BlockSpec((kt, h), lambda i: (i, 0)),
            pl.BlockSpec((kt, h), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((t, h), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((t, h), jnp.float32),
            pltpu.VMEM((t, h), jnp.float32),
        ],
        interpret=True,
    )(xs, wg, wu)
