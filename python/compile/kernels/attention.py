"""Multi-head attention Pallas kernel (per-head grid).

Computes softmax(q k^T / sqrt(hd) + mask) v for T query tokens over S
key/value slots. The KV-cache mask arrives as a float vector (1.0 = valid
slot); invalid slots get a large negative additive bias.

Grid iterates over heads; each step stages one head's [T, hd] queries and
[S, hd] keys/values into VMEM. T and S are small in the frame-append/decode
stages (<= a few hundred), so a whole head fits comfortably in VMEM and the
softmax runs unblocked.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF


def _mha_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale):
    q = q_ref[0]  # [T, hd]
    k = k_ref[0]  # [S, hd]
    v = v_ref[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = scores + (1.0 - mask_ref[...])[None, :] * NEG_INF
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("num_heads",))
def mha_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: jax.Array,
    num_heads: int,
):
    """q: [T, nh*hd]; k, v: [S, nh*hd]; mask: [S] -> [T, nh*hd]."""
    t, d = q.shape
    s = k.shape[0]
    assert d % num_heads == 0
    hd = d // num_heads
    qh = q.reshape(t, num_heads, hd).transpose(1, 0, 2)  # [nh, T, hd]
    kh = k.reshape(s, num_heads, hd).transpose(1, 0, 2)
    vh = v.reshape(s, num_heads, hd).transpose(1, 0, 2)
    out = pl.pallas_call(
        functools.partial(_mha_kernel, scale=1.0 / (hd**0.5)),
        grid=(num_heads,),
        in_specs=[
            pl.BlockSpec((1, t, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((s,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((1, t, hd), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_heads, t, hd), jnp.float32),
        interpret=True,
    )(qh, kh, vh, mask)
    return out.transpose(1, 0, 2).reshape(t, d)
