"""L2: the VLM transformer-block compute graph, staged for flash-in-the-loop
serving.

The Rust coordinator sparsifies *each weight matrix by its own input
activation* (predictor-free, following the paper / TEAL). Between matrices
it must observe intermediate activations to score + chunk-select + load the
next matrix's rows from flash. A transformer block therefore lowers to
three executables, invoked per layer with freshly loaded (gathered) rows:

  1. qkv_attn  : xs[T,R], wq/wk/wv[R,d], kv-cache -> (attn[T,d], k, v)
  2. proj_res  : a_sel[T,R], w[R,N], res[T,N] -> x'[T,N]   (o-proj & down-proj)
  3. gateup    : xs[T,R], wg[R,H], wu[R,H] -> act[T,H]     (SwiGLU)

R is a budget bucket: Rust rounds its chunk-selection budget up to the
nearest compiled bucket and zero-pads, which is numerically exact (zero
rows contribute nothing to any contraction).

RMSNorm and activation scoring run host-side in Rust — they are O(T*d)
vector ops the coordinator needs the values of anyway.
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels.attention import mha_attention
from .kernels.gated_mlp import fused_gateup
from .kernels.sparse_matmul import gathered_matmul


@dataclass(frozen=True)
class ModelDims:
    """Dimensions of a runnable (small, real) model variant."""

    name: str
    d: int  # hidden size
    h: int  # MLP intermediate size
    nh: int  # attention heads
    t: int  # tokens per frame
    c: int  # KV-cache capacity (slots)
    layers: int
    # Budget-bucket fractions over an input dim, rounded to multiples of 16.
    fractions: tuple = (1.0, 0.75, 0.5, 0.375, 0.25)

    def buckets(self, n: int) -> list:
        out = []
        for f in self.fractions:
            r = max(16, int(round(n * f / 16)) * 16)
            r = min(r, n)
            if r not in out:
                out.append(r)
        return out

    @property
    def d_buckets(self):
        return self.buckets(self.d)

    @property
    def h_buckets(self):
        return self.buckets(self.h)


TINY = ModelDims(name="tiny", d=64, h=192, nh=4, t=8, c=32, layers=2)
SMALL = ModelDims(name="small", d=256, h=768, nh=4, t=16, c=128, layers=4)
BASE = ModelDims(name="base", d=512, h=1536, nh=8, t=32, c=256, layers=8)

MODELS = {m.name: m for m in (TINY, SMALL, BASE)}


def make_qkv_attn(dims: ModelDims, t: int):
    """Fused QKV projection + attention over cache+frame. t=1 for decode."""

    def qkv_attn(xs, wq, wk, wv, kc, vc, mask):
        q = gathered_matmul(xs, wq)
        k = gathered_matmul(xs, wk)
        v = gathered_matmul(xs, wv)
        keys = jnp.concatenate([kc, k], axis=0)
        vals = jnp.concatenate([vc, v], axis=0)
        full_mask = jnp.concatenate([mask, jnp.ones((t,), mask.dtype)])
        attn = mha_attention(q, keys, vals, full_mask, dims.nh)
        return attn, k, v

    return qkv_attn


def proj_residual(a_sel, w, res):
    """Gathered output projection + residual add (o-proj and down-proj)."""
    return (res + gathered_matmul(a_sel, w),)


def gateup(xs, wg, wu):
    """Gathered SwiGLU gate/up."""
    return (fused_gateup(xs, wg, wu),)


def artifact_specs(dims: ModelDims):
    """Enumerate every (name, fn, example-arg-specs) artifact for a model.

    Returns a list of dicts consumed by aot.py and mirrored into
    artifacts/manifest.json for the Rust runtime.
    """
    f32 = jnp.float32
    specs = []

    def shape(*s):
        return jnp.zeros(s, f32)  # only shapes matter; zeros keep it cheap

    for r in dims.d_buckets:
        for t, stage in ((dims.t, "append"), (1, "decode")):
            specs.append(
                dict(
                    name=f"qkv_{stage}_{dims.name}_r{r}",
                    kind=f"qkv_{stage}",
                    model=dims.name,
                    r=r,
                    t=t,
                    fn=make_qkv_attn(dims, t),
                    args=[
                        shape(t, r),  # xs
                        shape(r, dims.d),  # wq
                        shape(r, dims.d),  # wk
                        shape(r, dims.d),  # wv
                        shape(dims.c, dims.d),  # kc
                        shape(dims.c, dims.d),  # vc
                        shape(dims.c),  # mask
                    ],
                    outputs=3,
                )
            )
        for t, stage in ((dims.t, "gateup"), (1, "gateup_dec")):
            specs.append(
                dict(
                    name=f"{stage}_{dims.name}_r{r}",
                    kind=stage,
                    model=dims.name,
                    r=r,
                    t=t,
                    fn=gateup,
                    args=[shape(t, r), shape(r, dims.h), shape(r, dims.h)],
                    outputs=1,
                )
            )
    # proj_residual: o-proj uses d-buckets (input = attn out, dim d);
    # down-proj uses h-buckets (input = MLP activation, dim h). Output is
    # always d. Compile the union of buckets, for frame-T and decode (t=1).
    proj_buckets = sorted(set(dims.d_buckets) | set(dims.h_buckets))
    for r in proj_buckets:
        for t, stage in ((dims.t, "projres"), (1, "projres_dec")):
            specs.append(
                dict(
                    name=f"{stage}_{dims.name}_r{r}",
                    kind=stage,
                    model=dims.name,
                    r=r,
                    t=t,
                    fn=proj_residual,
                    args=[shape(t, r), shape(r, dims.d), shape(t, dims.d)],
                    outputs=1,
                )
            )
    return specs
