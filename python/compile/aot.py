"""AOT lowering: jax stage functions -> HLO text artifacts + manifest.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts [--models tiny,small]

Emits one `<name>.hlo.txt` per artifact plus `manifest.json` describing
input/output shapes, consumed by rust/src/runtime/.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import MODELS, artifact_specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, regardless of output arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(spec) -> str:
    args = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in spec["args"]]
    return to_hlo_text(jax.jit(spec["fn"]).lower(*args))


def build(out_dir: str, model_names, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "models": {}, "artifacts": []}
    for mname in model_names:
        dims = MODELS[mname]
        manifest["models"][mname] = dict(
            d=dims.d,
            h=dims.h,
            nh=dims.nh,
            t=dims.t,
            c=dims.c,
            layers=dims.layers,
            d_buckets=dims.d_buckets,
            h_buckets=dims.h_buckets,
        )
        for spec in artifact_specs(dims):
            fname = f"{spec['name']}.hlo.txt"
            text = lower_spec(spec)
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                dict(
                    name=spec["name"],
                    file=fname,
                    kind=spec["kind"],
                    model=spec["model"],
                    r=spec["r"],
                    t=spec["t"],
                    inputs=[list(a.shape) for a in spec["args"]],
                    outputs=spec["outputs"],
                    sha256=hashlib.sha256(text.encode()).hexdigest()[:16],
                )
            )
            if verbose:
                print(f"  {fname}  ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # Flat TSV mirror for the Rust runtime (offline env has no JSON crate).
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        for mname, md in manifest["models"].items():
            f.write(
                "model\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n".format(
                    mname,
                    md["d"],
                    md["h"],
                    md["nh"],
                    md["t"],
                    md["c"],
                    md["layers"],
                    ",".join(str(b) for b in md["d_buckets"]),
                    ",".join(str(b) for b in md["h_buckets"]),
                )
            )
        for a in manifest["artifacts"]:
            shapes = ";".join(
                ",".join(str(d) for d in s) if s else "scalar"
                for s in a["inputs"]
            )
            f.write(
                "artifact\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n".format(
                    a["name"],
                    a["file"],
                    a["kind"],
                    a["model"],
                    a["r"],
                    a["t"],
                    a["outputs"],
                    shapes,
                )
            )
    if verbose:
        print(f"wrote {len(manifest['artifacts'])} artifacts -> {out_dir}")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument(
        "--models",
        default="tiny,small",
        help="comma-separated model names (tiny,small,base)",
    )
    a = p.parse_args()
    build(a.out_dir, [m for m in a.models.split(",") if m])


if __name__ == "__main__":
    main()
