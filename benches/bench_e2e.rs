//! End-to-end engine benches: whole frame-append and decode steps per
//! policy on the runnable model — the serving-loop numbers behind Fig 8
//! and the §Perf log in EXPERIMENTS.md.

use std::path::Path;

use neuron_chunking::benchlib::{black_box, header, Bencher};
use neuron_chunking::coordinator::{Engine, EngineConfig, Policy};
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::storage::DeviceProfile;
use neuron_chunking::workload::FrameTrace;

fn main() {
    header("e2e engine (frame append / decode per policy, tiny model)");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let sat_kb = DeviceProfile::nano().saturation_bytes(0.99) as f64 / 1024.0;
    let mut b = Bencher::new(std::time::Duration::from_millis(600), 8);

    for (label, policy, sparsity) in [
        ("dense", Policy::Dense, 0.0),
        ("topk s=0.5", Policy::TopK, 0.5),
        (
            "chunking s=0.5",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, sat_kb),
            },
            0.5,
        ),
    ] {
        let mut engine =
            Engine::new(EngineConfig::new("tiny", policy, sparsity), &dir).unwrap();
        engine.warmup().unwrap();
        let spec = engine.spec().clone();
        let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 5);
        let frame = trace.frame(0);
        engine.append_frame(0, &frame).unwrap(); // warm
        b.bench(&format!("append_frame tiny [{label}]"), || {
            black_box(engine.append_frame(0, &frame).unwrap());
        });
        let token = vec![0.1f32; spec.d];
        b.bench(&format!("decode_step  tiny [{label}]"), || {
            black_box(engine.decode_step(0, &token).unwrap());
        });
    }

    // Experiment-harness point cost (what figure sweeps pay per point).
    use neuron_chunking::experiments::{IoPolicy, PaperRig, RigConfig};
    use neuron_chunking::model::ModelSpec;
    use neuron_chunking::workload::DatasetSpec;
    let rig = PaperRig::new(
        ModelSpec::llava_7b(),
        DeviceProfile::nano(),
        RigConfig {
            calib_samples: 8,
            tokens_per_frame: 0,
            seed: 1,
        },
    )
    .unwrap();
    let ds = DatasetSpec::tempcompass();
    b.bench("paper-rig run_point llava-7b (3 frames)", || {
        black_box(rig.run_point(&IoPolicy::Chunking, 0.4, &ds, 3).unwrap());
    });
}
