//! End-to-end engine benches: whole frame-append and decode steps per
//! policy on the runnable model — the serving-loop numbers behind Fig 8
//! and the §Perf log in EXPERIMENTS.md.

use std::path::Path;

use neuron_chunking::benchlib::{black_box, header, Bencher};
use neuron_chunking::coordinator::{Engine, Policy};
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::storage::DeviceProfile;
use neuron_chunking::workload::FrameTrace;

fn main() {
    header("e2e engine (frame append / decode per policy, tiny model)");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let sat_kb = DeviceProfile::nano().saturation_bytes(0.99) as f64 / 1024.0;
    let mut b = Bencher::new(std::time::Duration::from_millis(600), 8);

    for (label, policy, sparsity) in [
        ("dense", Policy::Dense, 0.0),
        ("topk s=0.5", Policy::TopK, 0.5),
        (
            "chunking s=0.5",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, sat_kb),
            },
            0.5,
        ),
    ] {
        for prefetch in [false, true] {
            let engine = Engine::builder("tiny")
                .policy(policy.clone())
                .sparsity(sparsity)
                .prefetch(prefetch)
                .artifacts(&dir)
                .build()
                .unwrap();
            engine.warmup().unwrap();
            let spec = engine.spec();
            let session = engine.new_session();
            let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 5);
            let frame = trace.frame(0);
            session.append_frame(&frame).unwrap(); // warm
            let pf = if prefetch { "+pf" } else { "   " };
            b.bench(&format!("append_frame tiny [{label}]{pf}"), || {
                black_box(session.append_frame(&frame).unwrap());
            });
            let token = vec![0.1f32; spec.d];
            b.bench(&format!("decode_step  tiny [{label}]{pf}"), || {
                black_box(session.decode_step(&token).unwrap());
            });
        }
    }

    // Experiment-harness point cost (what figure sweeps pay per point).
    use neuron_chunking::experiments::{IoPolicy, PaperRig, RigConfig};
    use neuron_chunking::model::ModelSpec;
    use neuron_chunking::workload::DatasetSpec;
    let rig = PaperRig::new(
        ModelSpec::llava_7b(),
        DeviceProfile::nano(),
        RigConfig {
            calib_samples: 8,
            tokens_per_frame: 0,
            seed: 1,
        },
    )
    .unwrap();
    let ds = DatasetSpec::tempcompass();
    b.bench("paper-rig run_point llava-7b (3 frames)", || {
        black_box(rig.run_point(&IoPolicy::Chunking, 0.4, &ds, 3).unwrap());
    });
}
