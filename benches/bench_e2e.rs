//! End-to-end engine benches: whole frame-append and decode steps per
//! policy on the runnable model — the serving-loop numbers behind Fig 8
//! and the §Perf log in EXPERIMENTS.md.
//!
//! Besides the human-readable table, this bench emits a machine-readable
//! `BENCH_e2e.json` (override the path with `NC_BENCH_JSON`) so the perf
//! trajectory is tracked across PRs: per policy × prefetch × thread
//! count, decode/append tokens-per-second plus p50/p99 step latency, a
//! multi-stream scaling sweep that drives N concurrent sessions over
//! the shared `Sync` engine core from N OS threads, a storage-pool
//! device sweep, an async I/O overlap sweep against a wall-clock
//! file-backed pool (sync vs queue depths {1, 2, 4}), a
//! cross-stream batch-scaling sweep (fused decode batches over
//! {1, 2, 4} streams, tokens/s + shared-bytes dedup ratio), and a
//! mixed-workload sweep (decode tail under a prefill flood, monolithic
//! vs chunked prefill through the two-queue scheduler).
//!
//! CI gates on this report: `bench-gate` (scripts/bench_gate.rs) diffs
//! it against the committed `BENCH_baseline.json` and fails on >15%
//! tokens/s or p99 regression.

use std::path::Path;
use std::time::Instant;

use neuron_chunking::benchlib::{black_box, header, Bencher};
use neuron_chunking::coordinator::{DecodeRequest, Engine, Policy, StageStats};
use neuron_chunking::sparsify::ChunkSelectConfig;
use neuron_chunking::stats;
use neuron_chunking::storage::DeviceProfile;
use neuron_chunking::workload::FrameTrace;

/// One emitted measurement row.
struct Entry {
    mode: &'static str,
    /// On-flash storage dtype serving the row ("f32" everywhere except
    /// the dtype_sweep arms) — part of the gate's identity key.
    dtype: &'static str,
    policy: &'static str,
    prefetch: bool,
    threads: usize,
    streams: usize,
    devices: usize,
    /// Async I/O pipeline on (queue_depth then records the bound).
    async_io: bool,
    queue_depth: usize,
    op: &'static str,
    tokens_per_s: f64,
    p50_us: f64,
    p99_us: f64,
    samples: usize,
}

impl Entry {
    fn to_json(&self) -> String {
        format!(
            "{{\"mode\":\"{}\",\"dtype\":\"{}\",\"policy\":\"{}\",\"prefetch\":{},\"threads\":{},\
             \"streams\":{},\"devices\":{},\"async_io\":{},\"queue_depth\":{},\
             \"op\":\"{}\",\"tokens_per_s\":{:.3},\
             \"p50_us\":{:.3},\"p99_us\":{:.3},\"samples\":{}}}",
            self.mode,
            self.dtype,
            self.policy,
            self.prefetch,
            self.threads,
            self.streams,
            self.devices,
            self.async_io,
            self.queue_depth,
            self.op,
            self.tokens_per_s,
            self.p50_us,
            self.p99_us,
            self.samples
        )
    }
}

fn percentiles_us(samples: &[f64]) -> (f64, f64) {
    (
        stats::percentile(samples, 50.0) * 1e6,
        stats::percentile(samples, 99.0) * 1e6,
    )
}

fn build_engine(policy: &Policy, sparsity: f64, prefetch: bool, threads: usize) -> Engine {
    build_engine_devices(policy, sparsity, prefetch, threads, 1)
}

fn build_engine_devices(
    policy: &Policy,
    sparsity: f64,
    prefetch: bool,
    threads: usize,
    devices: usize,
) -> Engine {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    // Async I/O is pinned off here so every row's identity fields stay
    // truthful regardless of NC_ASYNC_IO; the overlap sweep below builds
    // its engines explicitly.
    let engine = Engine::builder("tiny")
        .policy(policy.clone())
        .sparsity(sparsity)
        .prefetch(prefetch)
        .exec_threads(threads)
        .devices(devices)
        .async_io(false)
        .artifacts(&dir)
        .build()
        .unwrap();
    engine.warmup().unwrap();
    engine
}

/// Per-step latency samples for one op on a warmed session.
fn sample_steps<F: FnMut()>(n: usize, mut step: F) -> Vec<f64> {
    (0..n)
        .map(|_| {
            let t0 = Instant::now();
            step();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

fn main() {
    header("e2e engine (frame append / decode per policy, tiny model)");
    let sat_kb = DeviceProfile::nano().saturation_bytes(0.99) as f64 / 1024.0;
    let mut b = Bencher::new(std::time::Duration::from_millis(600), 8);
    let mut entries: Vec<Entry> = Vec::new();
    let quick = std::env::var("NC_BENCH_QUICK").is_ok();
    let decode_samples = if quick { 32 } else { 128 };
    let append_samples = if quick { 8 } else { 32 };

    let policies: [(&'static str, Policy, f64); 3] = [
        ("dense", Policy::Dense, 0.0),
        ("topk", Policy::TopK, 0.5),
        (
            "chunking",
            Policy::Chunking {
                config: ChunkSelectConfig::new(2.0, 2.0, sat_kb),
            },
            0.5,
        ),
    ];

    // --- single-session sweep: policy × prefetch, exec_threads = 1 ---
    for (label, policy, sparsity) in &policies {
        for prefetch in [false, true] {
            let engine = build_engine(policy, *sparsity, prefetch, 1);
            let spec = engine.spec();
            let session = engine.new_session();
            let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 5);
            let frame = trace.frame(0);
            let mut out = Vec::new();
            session.append_frame_into(&frame, &mut out).unwrap(); // warm
            let pf = if prefetch { "+pf" } else { "   " };
            b.bench(&format!("append_frame tiny [{label} s={sparsity}]{pf}"), || {
                black_box(session.append_frame_into(&frame, &mut out).unwrap());
            });
            let token = vec![0.1f32; spec.d];
            session.decode_step_into(&token, &mut out).unwrap(); // warm
            b.bench(&format!("decode_step  tiny [{label} s={sparsity}]{pf}"), || {
                black_box(session.decode_step_into(&token, &mut out).unwrap());
            });

            // Per-step samples for the JSON report.
            let samples = sample_steps(append_samples, || {
                black_box(session.append_frame_into(&frame, &mut out).unwrap());
            });
            let (p50, p99) = percentiles_us(&samples);
            entries.push(Entry {
                mode: "single",
                dtype: "f32",
                policy: *label,
                prefetch,
                threads: 1,
                streams: 1,
                devices: 1,
                async_io: false,
                queue_depth: 0,
                op: "append",
                tokens_per_s: spec.tokens_per_frame as f64 / stats::mean(&samples),
                p50_us: p50,
                p99_us: p99,
                samples: samples.len(),
            });
            let samples = sample_steps(decode_samples, || {
                black_box(session.decode_step_into(&token, &mut out).unwrap());
            });
            let (p50, p99) = percentiles_us(&samples);
            entries.push(Entry {
                mode: "single",
                dtype: "f32",
                policy: *label,
                prefetch,
                threads: 1,
                streams: 1,
                devices: 1,
                async_io: false,
                queue_depth: 0,
                op: "decode",
                tokens_per_s: 1.0 / stats::mean(&samples),
                p50_us: p50,
                p99_us: p99,
                samples: samples.len(),
            });
        }
    }

    // --- exec-thread sweep: kernel-level parallelism, one session ---
    for (label, policy, sparsity) in &policies {
        for threads in [2usize, 4] {
            let engine = build_engine(policy, *sparsity, true, threads);
            let spec = engine.spec();
            let session = engine.new_session();
            let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 5);
            let frame = trace.frame(0);
            let token = vec![0.1f32; spec.d];
            let mut out = Vec::new();
            session.append_frame_into(&frame, &mut out).unwrap();
            session.decode_step_into(&token, &mut out).unwrap();
            let samples = sample_steps(decode_samples, || {
                black_box(session.decode_step_into(&token, &mut out).unwrap());
            });
            let (p50, p99) = percentiles_us(&samples);
            b.bench(&format!("decode_step  tiny [{label}] xt={threads}"), || {
                black_box(session.decode_step_into(&token, &mut out).unwrap());
            });
            entries.push(Entry {
                mode: "exec_threads",
                dtype: "f32",
                policy: *label,
                prefetch: true,
                threads,
                streams: 1,
                devices: 1,
                async_io: false,
                queue_depth: 0,
                op: "decode",
                tokens_per_s: 1.0 / stats::mean(&samples),
                p50_us: p50,
                p99_us: p99,
                samples: samples.len(),
            });
        }
    }

    // --- multi-stream scaling: N sessions on N OS threads, shared core ---
    for (label, policy, sparsity) in &policies {
        for threads in [1usize, 2, 4] {
            let engine = build_engine(policy, *sparsity, true, 1);
            let spec = engine.spec();
            let d = spec.d;
            let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, threads + 1, 5);
            let per_stream = decode_samples;
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for stream in 0..threads {
                    let engine = engine.clone();
                    let frame = trace.frame(stream);
                    s.spawn(move || {
                        let session = engine.new_session();
                        let mut out = Vec::new();
                        session.append_frame_into(&frame, &mut out).unwrap();
                        let token = vec![0.1f32; d];
                        session.decode_step_into(&token, &mut out).unwrap(); // warm
                        for _ in 0..per_stream {
                            black_box(session.decode_step_into(&token, &mut out).unwrap());
                        }
                    });
                }
            });
            let wall = t0.elapsed().as_secs_f64();
            let total_tokens = (threads * per_stream) as f64;
            println!(
                "{:<56} {:>12.0} tok/s  ({} streams x {} decodes)",
                format!("scaling decode tiny [{label}] threads={threads}"),
                total_tokens / wall,
                threads,
                per_stream
            );
            entries.push(Entry {
                mode: "scaling",
                dtype: "f32",
                policy: *label,
                prefetch: true,
                threads,
                streams: threads,
                devices: 1,
                async_io: false,
                queue_depth: 0,
                op: "decode",
                tokens_per_s: total_tokens / wall,
                p50_us: 0.0,
                p99_us: 0.0,
                samples: threads * per_stream,
            });
        }
    }

    // --- device-count sweep: sharded storage pool, decode + append ---
    // Outputs are bit-identical across pool sizes; what the sweep tracks
    // is how accounted (virtual) I/O service and wall throughput respond
    // to striping the flash image over 1/2/4 simulated members.
    let mut device_entries: Vec<Entry> = Vec::new();
    for (label, policy, sparsity) in &policies {
        for devices in [1usize, 2, 4] {
            let engine = build_engine_devices(policy, *sparsity, true, 1, devices);
            let spec = engine.spec();
            let session = engine.new_session();
            let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 5);
            let frame = trace.frame(0);
            let token = vec![0.1f32; spec.d];
            let mut out = Vec::new();
            session.append_frame_into(&frame, &mut out).unwrap();
            session.decode_step_into(&token, &mut out).unwrap(); // warm
            let samples = sample_steps(decode_samples, || {
                black_box(session.decode_step_into(&token, &mut out).unwrap());
            });
            let (p50, p99) = percentiles_us(&samples);
            println!(
                "{:<56} {:>12.0} tok/s",
                format!("device_scaling decode tiny [{label}] devices={devices}"),
                1.0 / stats::mean(&samples)
            );
            device_entries.push(Entry {
                mode: "device_scaling",
                dtype: "f32",
                policy: *label,
                prefetch: true,
                threads: 1,
                streams: 1,
                devices,
                async_io: false,
                queue_depth: 0,
                op: "decode",
                tokens_per_s: 1.0 / stats::mean(&samples),
                p50_us: p50,
                p99_us: p99,
                samples: samples.len(),
            });
        }
    }

    // --- async I/O overlap sweep: wall-clock file-backed pool ---
    // The sweep the tentpole claim rests on: the same workload served
    // from *real* per-member backing files (wall-clock reads), with the
    // synchronous inline-prefetch path vs the async pipeline at queue
    // depths {1, 2, 4}. With async on, next-layer reads proceed on the
    // I/O workers while kernels execute, so decode wall time drops by
    // the overlapped service.
    let mut async_entries: Vec<Entry> = Vec::new();
    let backing_root = std::env::temp_dir().join(format!("nc_bench_async_{}", std::process::id()));
    for (label, policy, sparsity) in &policies {
        if *label == "topk" {
            continue; // dense + chunking bracket the selection spectrum
        }
        for (async_io, depth) in [(false, 0usize), (true, 1), (true, 2), (true, 4)] {
            let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            let engine = Engine::builder("tiny")
                .policy(policy.clone())
                .sparsity(*sparsity)
                .prefetch(true)
                .devices(2)
                .file_backed(&backing_root)
                .async_io(async_io)
                .io_queue_depth(depth.max(1))
                .artifacts(&dir)
                .build()
                .unwrap();
            engine.warmup().unwrap();
            let spec = engine.spec();
            let session = engine.new_session();
            let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 5);
            let frame = trace.frame(0);
            let token = vec![0.1f32; spec.d];
            let mut out = Vec::new();
            session.append_frame_into(&frame, &mut out).unwrap();
            session.decode_step_into(&token, &mut out).unwrap(); // warm
            let samples = sample_steps(decode_samples, || {
                black_box(session.decode_step_into(&token, &mut out).unwrap());
            });
            let (p50, p99) = percentiles_us(&samples);
            println!(
                "{:<56} {:>12.0} tok/s",
                format!(
                    "async_overlap decode tiny [{label}] async={async_io} qd={depth}"
                ),
                1.0 / stats::mean(&samples)
            );
            async_entries.push(Entry {
                mode: "async_overlap",
                dtype: "f32",
                policy: *label,
                prefetch: true,
                threads: 1,
                streams: 1,
                devices: 2,
                async_io,
                queue_depth: depth,
                op: "decode",
                tokens_per_s: 1.0 / stats::mean(&samples),
                p50_us: p50,
                p99_us: p99,
                samples: samples.len(),
            });
        }
    }
    std::fs::remove_dir_all(&backing_root).ok();

    // --- batch_scaling sweep: cross-stream fused decode batches ---
    // N sessions decode as one fused batch per step: shared chunks are
    // read once (`io.shared_bytes`) and shared weight tiles run once
    // across all member activations. Outputs are bit-identical to solo
    // decoding; the sweep tracks aggregate tokens/s and the dedup ratio
    // as the batch deepens.
    let mut batch_entries: Vec<(Entry, f64)> = Vec::new();
    for (label, policy, sparsity) in &policies {
        if *label == "topk" {
            continue; // dense + chunking bracket the selection spectrum
        }
        for streams in [1usize, 2, 4] {
            let engine = build_engine(policy, *sparsity, true, 1);
            let spec = engine.spec();
            let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, streams + 1, 5);
            let sessions: Vec<_> = (0..streams).map(|_| engine.new_session()).collect();
            let mut out = Vec::new();
            for (i, s) in sessions.iter().enumerate() {
                s.append_frame_into(&trace.frame(i), &mut out).unwrap();
            }
            let token = vec![0.1f32; spec.d];
            let reqs: Vec<DecodeRequest> = sessions
                .iter()
                .map(|s| DecodeRequest {
                    session: s,
                    token: &token,
                })
                .collect();
            let mut outs: Vec<Vec<f32>> = vec![Vec::new(); streams];
            let mut st = vec![StageStats::default(); streams];
            engine.decode_batch_into(&reqs, &mut outs, &mut st).unwrap(); // warm
            // Snapshot the I/O counters after warm-up so the recorded
            // dedup ratio covers exactly the sampled batched decodes
            // (priming appends and warm-up traffic excluded).
            let m0 = engine.metrics();
            let samples = sample_steps(decode_samples, || {
                black_box(engine.decode_batch_into(&reqs, &mut outs, &mut st).unwrap());
            });
            let (p50, p99) = percentiles_us(&samples);
            let tps = streams as f64 / stats::mean(&samples);
            let m = engine.metrics();
            let shared = m.bytes("io.shared_bytes") - m0.bytes("io.shared_bytes");
            let io_b = m.bytes("io") - m0.bytes("io");
            let ratio = shared as f64 / ((shared + io_b).max(1)) as f64;
            println!(
                "{:<56} {:>12.0} tok/s  (shared {:.1}%)",
                format!("batch_scaling decode tiny [{label}] streams={streams}"),
                tps,
                100.0 * ratio
            );
            batch_entries.push((
                Entry {
                    mode: "batch_scaling",
                    dtype: "f32",
                    policy: *label,
                    prefetch: true,
                    threads: 1,
                    streams,
                    devices: 1,
                    async_io: false,
                    queue_depth: 0,
                    op: "decode",
                    tokens_per_s: tps,
                    p50_us: p50,
                    p99_us: p99,
                    samples: samples.len(),
                },
                ratio,
            ));
        }
    }

    // --- fault_tail sweep: hedged vs unhedged tail under a straggler ---
    // The robustness claim in one number: the same routed plan submitted
    // through the async ticket path (`AsyncIoQueue::submit_hedged`)
    // against a file-backed replicated pool whose member 0 stalls a few
    // percent of its reads. Unhedged, every stall lands in the caller's
    // tail; hedged, the ticket waiter re-issues the straggler's commands
    // to the replica at the hedge deadline and completes from whichever
    // source wins, so p999 collapses from the stall duration to the
    // hedge budget. (The inline `fan_out_hedged` path drains stragglers
    // before returning, so only this async path shows the wall-clock
    // win.)
    let mut fault_entries: Vec<(Entry, f64)> = Vec::new();
    {
        use std::sync::Arc;
        use std::time::Duration;

        use neuron_chunking::latency::Chunk;
        use neuron_chunking::model::{MatrixId, MatrixKind, ModelSpec, WeightStore};
        use neuron_chunking::plan::{CoalescePolicy, IoPlanner, PlanReceipt, ShardedPlan};
        use neuron_chunking::storage::{
            AsyncIoQueue, DevicePool, FaultConfig, FaultInjector, HedgeConfig, PoolStats,
            StripeLayout, StripePolicy,
        };

        let s = WeightStore::new(ModelSpec::tiny(), false, 42);
        let image = s.build_image();
        let fault_samples = if quick { 128 } else { 512 };
        let root = std::env::temp_dir().join(format!("nc_bench_fault_{}", std::process::id()));
        std::fs::create_dir_all(&root).unwrap();
        let planner = IoPlanner::new(CoalescePolicy::contiguous());
        // Hot (replicated) region head: every extent is replica-covered,
        // so a straggling original always has somewhere to hedge to.
        let plan = planner.plan_chunks(
            &s.layout,
            MatrixId::new(0, MatrixKind::Up),
            &[Chunk::new(0, 16)],
            None,
        );
        let mut tails: Vec<f64> = Vec::new();
        for hedged in [false, true] {
            let stripe =
                StripeLayout::build_replicated(&s.layout, 2, StripePolicy::RoundRobin, None, 2);
            let shards = stripe.shard_image(&image);
            let paths: Vec<std::path::PathBuf> = shards
                .iter()
                .enumerate()
                .map(|(m, data)| {
                    let p = root.join(format!("member{m}.img"));
                    std::fs::write(&p, data).unwrap();
                    p
                })
                .collect();
            // Factor 0 disables hedging, so both arms run the identical
            // submit_hedged call site and the identical fault sequence
            // (fresh injector, same seed, same member-0 read order).
            let factor = if hedged { 4.0 } else { 0.0 };
            let mut pool = DevicePool::from_files(&paths, stripe, 2, false)
                .unwrap()
                .with_hedge(HedgeConfig {
                    factor,
                    floor: Duration::from_micros(500),
                });
            pool.wrap_members(|i, inner| {
                if i == 0 {
                    Arc::new(FaultInjector::new(
                        inner,
                        FaultConfig {
                            spike_rate: 0.03,
                            spike: Duration::from_millis(10),
                            ..FaultConfig::default()
                        },
                    ))
                } else {
                    inner
                }
            });
            let health = Some(pool.health());
            let queue = AsyncIoQueue::start_with_health(pool.member_arcs(), 2, health);
            let mut sharded = ShardedPlan::default();
            pool.route_plan(&plan, &mut sharded);
            let mut receipt = PlanReceipt::default();
            let mut scratch = PoolStats::default();
            for _ in 0..4 {
                receipt.presize_for(plan.cmds());
                let ticket = queue.submit_hedged(&sharded, &pool);
                ticket.wait_scatter(&mut receipt.bytes, &mut scratch).unwrap(); // warm
            }
            let samples = sample_steps(fault_samples, || {
                receipt.presize_for(plan.cmds());
                let ticket = queue.submit_hedged(&sharded, &pool);
                black_box(ticket.wait_scatter(&mut receipt.bytes, &mut scratch).unwrap());
            });
            let (p50, p99) = percentiles_us(&samples);
            let p999 = stats::percentile(&samples, 99.9) * 1e6;
            let h = pool.health().snapshot();
            let label = if hedged { "hedged" } else { "unhedged" };
            println!(
                "{:<56} {:>12.0} sub/s  p99={:.0}us p999={:.0}us hedges={} wins={}",
                format!("fault_tail submit [{label}] spike=3%x10ms"),
                1.0 / stats::mean(&samples),
                p99,
                p999,
                h.hedges,
                h.hedge_wins
            );
            tails.push(p999);
            fault_entries.push((
                Entry {
                    mode: "fault_tail",
                    dtype: "f32",
                    policy: "raw",
                    prefetch: false,
                    threads: 2,
                    streams: 1,
                    devices: 2,
                    async_io: true,
                    queue_depth: 2,
                    op: if hedged { "submit_hedged" } else { "submit_unhedged" },
                    tokens_per_s: 1.0 / stats::mean(&samples),
                    p50_us: p50,
                    p99_us: p99,
                    samples: samples.len(),
                },
                p999,
            ));
            drop(queue);
            for p in paths {
                std::fs::remove_file(p).ok();
            }
        }
        std::fs::remove_dir_all(&root).ok();
        println!(
            "fault_tail: hedged p999 {:.2}ms vs unhedged {:.2}ms",
            tails[1] / 1e3,
            tails[0] / 1e3
        );
    }

    // --- cache_warmup sweep: shared hot-chunk RAM cache, budgets 0/64/256 MB ---
    // The cache serves already-selected rows from RAM, so outputs are
    // bit-identical across budgets (pinned by test_chunk_cache); what
    // the sweep tracks is steady-state warm-cache decode throughput plus
    // the hit ratio (RAM-served bytes / total demand) and the flash
    // bytes saved per budget. Warm protocol: a few decodes accumulate
    // selection frequency, one maintenance pass admits the hot rows
    // (a no-op at budget 0), one settling decode, then the measured
    // window — so the recorded ratio covers exactly the sampled steps.
    let mut cache_entries: Vec<(Entry, f64, u64)> = Vec::new();
    for (mb, op) in [(0usize, "decode_mb0"), (64, "decode_mb64"), (256, "decode_mb256")] {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let engine = Engine::builder("tiny")
            .policy(Policy::TopK)
            .sparsity(0.5)
            .prefetch(true)
            .exec_threads(1)
            .async_io(false)
            .cache_mb(mb)
            .artifacts(&dir)
            .build()
            .unwrap();
        engine.warmup().unwrap();
        let spec = engine.spec();
        let session = engine.new_session();
        let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 5);
        let token = vec![0.1f32; spec.d];
        let mut out = Vec::new();
        session.append_frame_into(&trace.frame(0), &mut out).unwrap();
        for _ in 0..4 {
            session.decode_step_into(&token, &mut out).unwrap();
        }
        engine.maintain_cache().unwrap();
        session.decode_step_into(&token, &mut out).unwrap(); // settle
        let m0 = engine.metrics();
        let samples = sample_steps(decode_samples, || {
            black_box(session.decode_step_into(&token, &mut out).unwrap());
        });
        let (p50, p99) = percentiles_us(&samples);
        let m = engine.metrics();
        let hit = m.bytes("io.cache_hit_bytes") - m0.bytes("io.cache_hit_bytes");
        let flash = m.bytes("io") - m0.bytes("io");
        let ratio = hit as f64 / ((hit + flash).max(1)) as f64;
        // The gate script only reads tokens/s and tails, so the cache's
        // effectiveness floor is enforced right here: a nonzero budget
        // must actually save flash traffic (and a zero budget must not
        // invent hits).
        assert!(
            (mb == 0) == (hit == 0),
            "cache_warmup mb={mb}: saved {hit} bytes over the measured window"
        );
        println!(
            "{:<56} {:>12.0} tok/s  (hit {:.1}%, saved {} KiB)",
            format!("cache_warmup decode tiny [topk] mb={mb}"),
            1.0 / stats::mean(&samples),
            100.0 * ratio,
            hit / 1024
        );
        cache_entries.push((
            Entry {
                mode: "cache_warmup",
                dtype: "f32",
                policy: "topk",
                prefetch: true,
                threads: 1,
                streams: 1,
                devices: 1,
                async_io: false,
                queue_depth: 0,
                op,
                tokens_per_s: 1.0 / stats::mean(&samples),
                p50_us: p50,
                p99_us: p99,
                samples: samples.len(),
            },
            ratio,
            hit,
        ));
    }

    // --- dtype_sweep: quantized chunk storage f32/fp16/int8 ---
    // The quantization tentpole, measured where it pays: the same
    // workload served from a real file-backed pool at each storage
    // dtype, dense and chunk-selected. Narrower encodings move fewer
    // flash bytes per token at the same row budget (int8 rows are
    // ~1/4 of f32), which on the wall-clock pool shows up as decode
    // throughput. Each arm also decodes a step-aligned golden prefix
    // so the max output |delta| vs the f32 arm — exactly the storage
    // format's rounding through the forward pass — is recorded and
    // bounded in-bench.
    let mut dtype_entries: Vec<(Entry, f64, f64)> = Vec::new();
    {
        use neuron_chunking::model::DType;
        let backing_root =
            std::env::temp_dir().join(format!("nc_bench_dtype_{}", std::process::id()));
        let golden_steps = 8usize;
        for (label, policy, sparsity) in &policies {
            if *label == "topk" {
                continue; // dense + chunking bracket the selection spectrum
            }
            let mut f32_tps = 0.0f64;
            let mut f32_bpt = 0.0f64;
            let mut f32_golden: Vec<Vec<f32>> = Vec::new();
            for dtype in [DType::F32, DType::F16, DType::Int8] {
                let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
                let engine = Engine::builder("tiny")
                    .policy(policy.clone())
                    .sparsity(*sparsity)
                    .prefetch(true)
                    .exec_threads(1)
                    .async_io(false)
                    .dtype(dtype)
                    .file_backed(&backing_root)
                    .artifacts(&dir)
                    .build()
                    .unwrap();
                engine.warmup().unwrap();
                let spec = engine.spec();
                let session = engine.new_session();
                let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 5);
                let token = vec![0.1f32; spec.d];
                let mut out = Vec::new();
                session.append_frame_into(&trace.frame(0), &mut out).unwrap();
                // Step-aligned golden prefix: every arm decodes the same
                // sequence from the same appended frame, so arms differ
                // only in on-flash encoding.
                let mut golden: Vec<Vec<f32>> = Vec::new();
                for _ in 0..golden_steps {
                    session.decode_step_into(&token, &mut out).unwrap();
                    golden.push(out.clone());
                }
                let m0 = engine.metrics();
                let samples = sample_steps(decode_samples, || {
                    black_box(session.decode_step_into(&token, &mut out).unwrap());
                });
                let (p50, p99) = percentiles_us(&samples);
                let m = engine.metrics();
                let bytes_per_token =
                    (m.bytes("io") - m0.bytes("io")) as f64 / samples.len() as f64;
                let tps = 1.0 / stats::mean(&samples);
                let delta = if dtype == DType::F32 {
                    f32_tps = tps;
                    f32_bpt = bytes_per_token;
                    f32_golden = golden;
                    0.0
                } else {
                    let mut d = 0.0f64;
                    for (a, b) in golden.iter().zip(&f32_golden) {
                        for (&x, &y) in a.iter().zip(b) {
                            assert!(x.is_finite(), "dtype_sweep [{label}] non-finite output");
                            d = d.max((x - y).abs() as f64);
                        }
                    }
                    let peak = f32_golden
                        .iter()
                        .flat_map(|v| v.iter())
                        .fold(0.0f32, |mx, &v| mx.max(v.abs()));
                    let scale = peak as f64;
                    let rel_bound = if dtype == DType::F16 { 0.02 } else { 0.25 };
                    assert!(
                        d <= rel_bound * scale,
                        "dtype_sweep [{label}] {}: max |delta| {d} vs f32 exceeds {} \
                         (= {rel_bound} x max |f32| {scale})",
                        dtype.name(),
                        rel_bound * scale
                    );
                    // Narrower storage must strictly cut flash traffic at
                    // the same row budget (the tentpole's bytes claim).
                    assert!(
                        bytes_per_token < f32_bpt,
                        "dtype_sweep [{label}] {}: {bytes_per_token:.1} B/token did not \
                         shrink vs f32's {f32_bpt:.1}",
                        dtype.name()
                    );
                    d
                };
                println!(
                    "{:<56} {:>12.0} tok/s  ({:.0} B/token, max-delta {:.2e})",
                    format!("dtype_sweep decode tiny [{label}] dtype={}", dtype.name()),
                    tps,
                    bytes_per_token,
                    delta
                );
                dtype_entries.push((
                    Entry {
                        mode: "dtype_sweep",
                        dtype: dtype.name(),
                        policy: *label,
                        prefetch: true,
                        threads: 1,
                        streams: 1,
                        devices: 1,
                        async_io: false,
                        queue_depth: 0,
                        op: "decode",
                        tokens_per_s: tps,
                        p50_us: p50,
                        p99_us: p99,
                        samples: samples.len(),
                    },
                    bytes_per_token,
                    delta,
                ));
                if dtype == DType::Int8 && *label == "dense" {
                    // The wall-clock claim: the dense file-backed arm is
                    // I/O-bound, so ~4x fewer flash bytes must show up as
                    // higher decode throughput.
                    assert!(
                        tps > f32_tps,
                        "dtype_sweep [dense] int8 did not beat f32 on the file-backed \
                         pool ({tps:.0} vs {f32_tps:.0} tok/s)"
                    );
                }
            }
        }
        std::fs::remove_dir_all(&backing_root).ok();
    }

    // --- mixed_slo sweep: prefill/decode disaggregation trade-off ---
    // The same mixed workload — one latency-sensitive decode stream plus
    // a saturating prefill flood on three others — served by a
    // one-worker scheduler in two arms. `mixed_single` is the
    // non-disaggregated baseline (`prefill_chunk = 0`: a decode can
    // preempt *queued* prefills but never a running one, so its wait is
    // a whole monolithic prefill). `mixed_split` is the disaggregated
    // path (`prefill_chunk = 1`: the prefill yields at every layer
    // boundary and queued decodes interleave). Each arm reports decode
    // p50/p99 under flood plus the prefill throughput sustained
    // alongside — the trade-off curve the tentpole claims; the assert
    // below pins its direction (outputs stay bit-identical either way,
    // pinned by the scheduler tests).
    let mut mixed_entries: Vec<Entry> = Vec::new();
    {
        use std::collections::VecDeque;
        use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
        use std::time::Duration;

        use neuron_chunking::coordinator::{Request, Scheduler, SchedulerConfig};

        let mut decode_p99 = [0.0f64; 2];
        for (arm, (mode, chunk)) in [("mixed_single", 0usize), ("mixed_split", 1)]
            .into_iter()
            .enumerate()
        {
            let sched = Scheduler::spawn(
                SchedulerConfig::default()
                    .with_workers(1)
                    .with_batch_window(Duration::ZERO)
                    .with_slo(None)
                    .with_prefill_budget(0)
                    .with_prefill_chunk(chunk),
                || build_engine(&Policy::TopK, 0.5, true, 1),
            );
            let spec = sched.engine().spec();
            let trace = FrameTrace::new(spec.d, spec.tokens_per_frame, 4, 5);
            let token = vec![0.1f32; spec.d];
            sched
                .submit(Request::prefill(0, trace.frame(0)))
                .unwrap()
                .recv()
                .unwrap()
                .output
                .unwrap(); // prime the decode stream

            let stop = AtomicBool::new(false);
            let prefills_done = AtomicU64::new(0);
            let (samples, wall, flood) = std::thread::scope(|s| {
                let sched = &sched;
                let trace = &trace;
                let stop = &stop;
                let prefills_done = &prefills_done;
                s.spawn(move || {
                    // Keep ~6 prefills queued across streams 1..=3 for
                    // the whole measured window.
                    let mut pending = VecDeque::new();
                    let mut next = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        let stream = 1 + next % 3;
                        next += 1;
                        match sched.submit(Request::prefill(stream, trace.frame(stream))) {
                            Ok(rx) => pending.push_back(rx),
                            Err(_) => std::thread::sleep(Duration::from_micros(50)),
                        }
                        if pending.len() >= 6 {
                            let rx = pending.pop_front().unwrap();
                            if rx.recv().map(|c| c.output.is_ok()).unwrap_or(false) {
                                prefills_done.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    for rx in pending {
                        let _ = rx.recv(); // drain; past the window, uncounted
                    }
                });
                // Warm one decode through the flood, then measure.
                sched
                    .submit(Request::decode(0, token.clone()))
                    .unwrap()
                    .recv()
                    .unwrap()
                    .output
                    .unwrap();
                let t0 = Instant::now();
                let c0 = prefills_done.load(Ordering::Relaxed);
                let samples = sample_steps(decode_samples, || {
                    let rx = sched.submit(Request::decode(0, token.clone())).unwrap();
                    black_box(rx.recv().unwrap().output.unwrap());
                });
                let wall = t0.elapsed().as_secs_f64();
                let flood = prefills_done.load(Ordering::Relaxed) - c0;
                stop.store(true, Ordering::Relaxed);
                (samples, wall, flood)
            });
            sched.shutdown();

            let (p50, p99) = percentiles_us(&samples);
            decode_p99[arm] = p99;
            let prefill_tps = flood as f64 * spec.tokens_per_frame as f64 / wall;
            println!(
                "{:<56} {:>12.0} tok/s  p50={:.0}us p99={:.0}us (prefill {:.0} tok/s beside)",
                format!("{mode} decode tiny [topk] chunk={chunk}"),
                1.0 / stats::mean(&samples),
                p50,
                p99,
                prefill_tps
            );
            mixed_entries.push(Entry {
                mode: if chunk == 0 { "mixed_single" } else { "mixed_split" },
                dtype: "f32",
                policy: "topk",
                prefetch: true,
                threads: 1,
                streams: 4,
                devices: 1,
                async_io: false,
                queue_depth: 0,
                op: "decode",
                tokens_per_s: 1.0 / stats::mean(&samples),
                p50_us: p50,
                p99_us: p99,
                samples: samples.len(),
            });
            mixed_entries.push(Entry {
                mode: if chunk == 0 { "mixed_single" } else { "mixed_split" },
                dtype: "f32",
                policy: "topk",
                prefetch: true,
                threads: 1,
                streams: 4,
                devices: 1,
                async_io: false,
                queue_depth: 0,
                op: "prefill",
                tokens_per_s: prefill_tps,
                p50_us: 0.0,
                p99_us: 0.0,
                samples: flood as usize,
            });
        }
        // The direction of the trade-off is the acceptance criterion:
        // chunked prefill must cut the decode tail under flood (the
        // slack absorbs scheduler noise; the typical gap is ~2x on the
        // two-layer tiny model).
        assert!(
            decode_p99[1] <= decode_p99[0] * 1.10,
            "mixed_slo: chunked prefill did not improve decode p99 under flood \
             (single {:.0}us vs split {:.0}us)",
            decode_p99[0],
            decode_p99[1]
        );
    }

    // --- experiment-harness point cost (what figure sweeps pay) ---
    if !quick {
        use neuron_chunking::experiments::{IoPolicy, PaperRig, RigConfig};
        use neuron_chunking::model::ModelSpec;
        use neuron_chunking::workload::DatasetSpec;
        let rig = PaperRig::new(
            ModelSpec::llava_7b(),
            DeviceProfile::nano(),
            RigConfig {
                calib_samples: 8,
                tokens_per_frame: 0,
                seed: 1,
            },
        )
        .unwrap();
        let ds = DatasetSpec::tempcompass();
        b.bench("paper-rig run_point llava-7b (3 frames)", || {
            black_box(rig.run_point(&IoPolicy::Chunking, 0.4, &ds, 3).unwrap());
        });
    }

    // --- machine-readable report (redline-style stats file) ---
    let path = std::env::var("NC_BENCH_JSON").unwrap_or_else(|_| "BENCH_e2e.json".to_string());
    let rows: Vec<String> = entries.iter().map(|e| format!("  {}", e.to_json())).collect();
    let dev_rows: Vec<String> = device_entries
        .iter()
        .map(|e| format!("  {}", e.to_json()))
        .collect();
    let async_rows: Vec<String> = async_entries
        .iter()
        .map(|e| format!("  {}", e.to_json()))
        .collect();
    // Batch rows carry the fused-I/O dedup ratio as an extra field
    // (shared bytes / (shared + charged) — 0 means no cross-stream
    // overlap, 0.5 means every byte was demanded by two streams).
    let batch_rows: Vec<String> = batch_entries
        .iter()
        .map(|(e, ratio)| {
            let base = e.to_json();
            format!("  {},\"shared_ratio\":{:.4}}}", &base[..base.len() - 1], ratio)
        })
        .collect();
    // Fault-tail rows carry p999 as an extra field so the gate can hold
    // the hedged tail below the unhedged stall duration.
    let fault_rows: Vec<String> = fault_entries
        .iter()
        .map(|(e, p999)| {
            let base = e.to_json();
            format!("  {},\"p999_us\":{:.3}}}", &base[..base.len() - 1], p999)
        })
        .collect();
    // Cache rows carry the warm hit ratio (RAM-served bytes / total
    // demand over the measured window) and the absolute flash bytes
    // saved, so the gate can hold both above zero at nonzero budgets.
    let cache_rows: Vec<String> = cache_entries
        .iter()
        .map(|(e, ratio, saved)| {
            let base = e.to_json();
            format!(
                "  {},\"hit_ratio\":{:.4},\"bytes_saved\":{}}}",
                &base[..base.len() - 1],
                ratio,
                saved
            )
        })
        .collect();
    // Dtype rows carry the flash bytes moved per decoded token and the
    // max output |delta| vs the step-aligned f32 arm, so the gate can
    // hold the byte savings and the accuracy envelope alongside
    // throughput.
    let dtype_rows: Vec<String> = dtype_entries
        .iter()
        .map(|(e, bpt, delta)| {
            let base = e.to_json();
            format!(
                "  {},\"bytes_per_token\":{:.1},\"max_delta\":{:.6e}}}",
                &base[..base.len() - 1],
                bpt,
                delta
            )
        })
        .collect();
    // Mixed-workload rows: decode tail + prefill throughput per arm
    // (single-queue monolithic vs chunked/disaggregated).
    let mixed_rows: Vec<String> = mixed_entries
        .iter()
        .map(|e| format!("  {}", e.to_json()))
        .collect();
    let json = format!(
        "{{\n\"bench\":\"e2e\",\n\"model\":\"tiny\",\n\"entries\":[\n{}\n],\n\
         \"device_scaling\":[\n{}\n],\n\"async_overlap\":[\n{}\n],\n\
         \"batch_scaling\":[\n{}\n],\n\"fault_tail\":[\n{}\n],\n\
         \"cache_warmup\":[\n{}\n],\n\"dtype_sweep\":[\n{}\n],\n\"mixed_slo\":[\n{}\n]\n}}\n",
        rows.join(",\n"),
        dev_rows.join(",\n"),
        async_rows.join(",\n"),
        batch_rows.join(",\n"),
        fault_rows.join(",\n"),
        cache_rows.join(",\n"),
        dtype_rows.join(",\n"),
        mixed_rows.join(",\n")
    );
    std::fs::write(&path, &json).expect("write bench json");
    println!(
        "\nwrote {path} ({} entries + {} device-scaling + {} async-overlap + {} batch-scaling \
         + {} fault-tail + {} cache-warmup + {} dtype-sweep + {} mixed-slo entries)",
        entries.len(),
        device_entries.len(),
        async_entries.len(),
        batch_entries.len(),
        fault_entries.len(),
        cache_entries.len(),
        dtype_entries.len(),
        mixed_entries.len()
    );
}
