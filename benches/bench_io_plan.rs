//! I/O-planner benches: plan-construction throughput on paper-scale
//! selection masks, plus a fidelity check that planned latency estimates
//! track `SimulatedSsd::service_time` on both device profiles (the
//! property that makes planned cost comparable to simulated service
//! time).

use neuron_chunking::benchlib::{black_box, header, Bencher};
use neuron_chunking::latency::{chunks_from_mask, Chunk};
use neuron_chunking::model::{FlashLayout, MatrixId, MatrixKind, ModelSpec};
use neuron_chunking::plan::{CoalescePolicy, IoPlanner, PlanRequest};
use neuron_chunking::report::fmt_secs;
use neuron_chunking::rng::Rng;
use neuron_chunking::storage::{
    DeviceProfile, FlashDevice, ProfileConfig, Profiler, SimulatedSsd,
};

fn main() {
    header("I/O planner (construction throughput + estimate fidelity)");
    let spec = ModelSpec::llava_7b();
    let layout = FlashLayout::build(&spec, false);
    let planner = IoPlanner::new(CoalescePolicy::contiguous());
    let mut rng = Rng::new(5);

    // Plan-construction throughput on a full layer's sparse demand
    // (every matrix at ~50% row sparsity — the worst-case segment count
    // a serving step produces).
    let requests: Vec<PlanRequest> = spec
        .matrices()
        .iter()
        .map(|m| {
            let mask: Vec<bool> = (0..m.rows).map(|_| rng.bool(0.5)).collect();
            PlanRequest::new(MatrixId::new(0, m.kind), chunks_from_mask(&mask))
        })
        .collect();
    let segs: usize = requests.iter().map(|r| r.chunks.len()).sum();
    let mut b = Bencher::default();
    b.bench(
        &format!("plan 7-matrix layer, {segs} chunks (llava-7b, s=0.5)"),
        || {
            black_box(planner.plan(&layout, &requests, None));
        },
    );
    let probe = SimulatedSsd::timing_only(DeviceProfile::nano(), 1 << 40, 9);
    let sat = DeviceProfile::nano().saturation_bytes(0.99);
    let nano_table = Profiler::new(&probe, ProfileConfig::coarse(sat, 1024))
        .build_table()
        .unwrap();
    b.bench("plan + latency estimate (same demand)", || {
        black_box(planner.plan(&layout, &requests, Some(&nano_table)));
    });

    // Estimate fidelity: uniform chunk batches on the 7B down-projection,
    // planned estimate vs simulated service time, nano and agx.
    println!("\nestimate fidelity (planned vs simulated service time):");
    let id = MatrixId::new(0, MatrixKind::Down);
    let rows = spec.shape_of(MatrixKind::Down).rows;
    let mut worst: f64 = 1.0;
    for profile in [DeviceProfile::nano(), DeviceProfile::agx()] {
        let sat = profile.saturation_bytes(0.99);
        let probe = SimulatedSsd::timing_only(profile.clone(), 1 << 40, 9);
        let table = Profiler::new(&probe, ProfileConfig::coarse(sat, 1024))
            .build_table()
            .unwrap();
        let dev = SimulatedSsd::timing_only(
            profile.clone(),
            layout.total_bytes().max(1 << 33),
            11,
        );
        for &chunk_rows in &[1usize, 4, 16, 48] {
            let stride = chunk_rows * 2;
            let chunks: Vec<Chunk> = (0..64)
                .map(|i| Chunk::new(i * stride, chunk_rows))
                .filter(|c| c.end() <= rows)
                .collect();
            let plan = planner.plan_chunks(&layout, id, &chunks, Some(&table));
            let measured = dev
                .service_time(plan.cmds())
                .unwrap()
                .as_secs_f64();
            let ratio = plan.estimated_seconds / measured;
            worst = worst.max(ratio.max(1.0 / ratio));
            println!(
                "  {:>8} x {:>3} rows/chunk: planned {:>10} vs simulated {:>10}  (x{ratio:.2})",
                profile.name,
                chunk_rows,
                fmt_secs(plan.estimated_seconds),
                fmt_secs(measured),
            );
            assert!(
                (0.5..=2.0).contains(&ratio),
                "planned estimate diverges from simulated service time: \
                 {ratio:.2}x on {} at {chunk_rows} rows/chunk",
                profile.name
            );
        }
    }
    println!("worst-case divergence: {worst:.2}x (bound: 2.0x)");
}
