//! Latency-model benches: the per-selection costs inside the 2 ms budget
//! (contiguity extraction, table lookups, estimates) and profiling sweeps.

use neuron_chunking::benchlib::{black_box, header, Bencher};
use neuron_chunking::latency::{chunks_from_mask, ContiguityDistribution};
use neuron_chunking::rng::Rng;
use neuron_chunking::storage::{DeviceProfile, ProfileConfig, Profiler, SimulatedSsd};

fn main() {
    header("latency model (T[s] lookups + contiguity machinery)");
    let mut b = Bencher::default();
    let profile = DeviceProfile::agx();
    let dev = SimulatedSsd::timing_only(profile.clone(), 1 << 40, 1);
    let table = Profiler::new(
        &dev,
        ProfileConfig::coarse(profile.saturation_bytes(0.99), 7168),
    )
    .build_table()
    .unwrap();

    let mut rng = Rng::new(7);
    let mask: Vec<bool> = (0..18944).map(|_| rng.bool(0.55)).collect();
    b.bench("chunks_from_mask: 18944 rows", || {
        black_box(chunks_from_mask(&mask));
    });

    let chunks = chunks_from_mask(&mask);
    b.bench(
        &format!("estimate_chunks: {} chunks", chunks.len()),
        || {
            black_box(table.estimate_chunks(&chunks));
        },
    );

    b.bench("estimate_mask: 18944 rows end-to-end", || {
        black_box(table.estimate_mask(&mask));
    });

    let dist = ContiguityDistribution::from_mask(&mask);
    b.bench("distribution stats (mean/mode/cdf)", || {
        black_box((dist.mean_chunk(), dist.mode_chunk(), dist.row_cdf()));
    });

    b.bench("latency_rows single lookup", || {
        black_box(table.latency_rows(black_box(37)));
    });

    // Full Appendix-D profile sweep (coarse) — the offline cost.
    b.bench("profiler: full coarse sweep (nano)", || {
        let p = DeviceProfile::nano();
        let d = SimulatedSsd::timing_only(p.clone(), 1 << 40, 3);
        black_box(
            Profiler::new(&d, ProfileConfig::coarse(p.saturation_bytes(0.99), 1024))
                .build_table()
                .unwrap(),
        );
    });
}
