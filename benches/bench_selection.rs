//! Selection-algorithm benches: Algorithm 1 vs baselines across the
//! paper's matrix shapes (Appendix H Table 2), enforcing the 2 ms
//! per-matrix runtime gate (Fig 13). Run via `cargo bench`.

use neuron_chunking::benchlib::{black_box, header, Bencher};
use neuron_chunking::rng::Rng;
use neuron_chunking::sparsify::{
    tuning, Bundling, ChunkSelect, ChunkSelectConfig, Selector, TopK,
};
use neuron_chunking::storage::{DeviceProfile, ProfileConfig, Profiler, SimulatedSsd};

fn main() {
    header("selection (Algorithm 1 vs baselines, paper shapes)");
    let profile = DeviceProfile::nano();
    let sat = profile.saturation_bytes(0.99);
    let probe = SimulatedSsd::timing_only(profile.clone(), 1 << 40, 1);
    let table = Profiler::new(&probe, ProfileConfig::coarse(sat, 1024))
        .build_table()
        .unwrap();

    let mut b = Bencher::default();
    let mut rng = Rng::new(2024);
    let mut gate_violations = 0;
    // The shapes dominating runtime (largest) + a small one, at the
    // paper's chosen hyperparameters for nano.
    for (rows, cols) in [(18944usize, 3584usize), (3584, 18944), (3584, 3584), (896, 4864)] {
        let row_bytes = cols * 2;
        let t = table.with_row_bytes(row_bytes);
        let importance: Vec<f32> = (0..rows).map(|_| rng.f32()).collect();
        let budget = (rows as f64 * 0.9) as usize; // sparsity 0.1 worst case
        let cfg = tuning::paper_config_for(rows, cols, "nano", sat as f64 / 1024.0)
            .unwrap_or_else(|| ChunkSelectConfig::new(8.0, 8.0, sat as f64 / 1024.0));

        let cs = ChunkSelect::new(cfg);
        let r = b.bench(&format!("chunk_select {rows}x{cols} (paper cfg)"), || {
            black_box(cs.select(&importance, budget, &t));
        });
        if r.median.as_secs_f64() * 1e3 > tuning::RUNTIME_GATE_MS {
            gate_violations += 1;
        }

        b.bench(&format!("topk         {rows}x{cols}"), || {
            black_box(TopK.select(&importance, budget, &t));
        });
        b.bench(&format!("bundling(2)  {rows}x{cols}"), || {
            black_box(Bundling::new(2).select(&importance, budget, &t));
        });
        // Candidate generation alone (the pre-sort stage).
        b.bench(&format!("candidates   {rows}x{cols}"), || {
            black_box(cs.candidates(&importance, &t));
        });
    }
    println!(
        "\n2 ms gate (Fig 13): {} violations across paper-configured shapes",
        gate_violations
    );
    assert_eq!(gate_violations, 0, "selection exceeded the paper's 2 ms gate");
}
