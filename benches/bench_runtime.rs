//! XLA runtime benches: per-artifact execution cost (the compute column
//! of Fig 8) across budget buckets, plus compile-time accounting.

use std::path::Path;

use neuron_chunking::benchlib::{black_box, header, Bencher};
use neuron_chunking::rng::Rng;
use neuron_chunking::runtime::{Tensor, XlaRuntime};

fn main() {
    header("runtime (AOT XLA execution per stage/bucket)");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = XlaRuntime::open(&dir).expect("run `make artifacts` first");
    let m = rt.manifest.model("small").unwrap().clone();
    let mut rng = Rng::new(1);
    let mut randt = |dims: Vec<usize>| {
        let n = dims.iter().product();
        Tensor::new(dims, (0..n).map(|_| rng.normal() as f32 * 0.2).collect())
    };

    let mut b = Bencher::default();

    // Compile cost (first-touch) for one artifact.
    let t0 = std::time::Instant::now();
    let name = format!("projres_small_r{}", m.d_buckets[0]);
    let a = randt(vec![m.t, m.d_buckets[0]]);
    let w = randt(vec![m.d_buckets[0], m.d]);
    let res = randt(vec![m.t, m.d]);
    rt.execute(&name, &[a.clone(), w.clone(), res.clone()]).unwrap();
    println!("first-touch compile+run of {name}: {:?}", t0.elapsed());

    for &r in &[m.d_buckets[0], *m.d_buckets.last().unwrap()] {
        let name = format!("projres_small_r{r}");
        let a = randt(vec![m.t, r]);
        let w = randt(vec![r, m.d]);
        let res = randt(vec![m.t, m.d]);
        b.bench(&format!("projres small r={r}"), || {
            black_box(rt.execute(&name, &[a.clone(), w.clone(), res.clone()]).unwrap());
        });
    }

    for &r in &[m.d_buckets[0], *m.d_buckets.last().unwrap()] {
        let name = format!("gateup_small_r{r}");
        let xs = randt(vec![m.t, r]);
        let wg = randt(vec![r, m.h]);
        let wu = randt(vec![r, m.h]);
        b.bench(&format!("gateup  small r={r}"), || {
            black_box(rt.execute(&name, &[xs.clone(), wg.clone(), wu.clone()]).unwrap());
        });
    }

    let r = m.d_buckets[1];
    let name = format!("qkv_append_small_r{r}");
    let xs = randt(vec![m.t, r]);
    let wq = randt(vec![r, m.d]);
    let wk = randt(vec![r, m.d]);
    let wv = randt(vec![r, m.d]);
    let kc = Tensor::zeros(vec![m.c, m.d]);
    let vc = Tensor::zeros(vec![m.c, m.d]);
    let mask = Tensor::zeros(vec![m.c]);
    b.bench(&format!("qkv_append small r={r} (attn incl.)"), || {
        black_box(
            rt.execute(
                &name,
                &[
                    xs.clone(),
                    wq.clone(),
                    wk.clone(),
                    wv.clone(),
                    kc.clone(),
                    vc.clone(),
                    mask.clone(),
                ],
            )
            .unwrap(),
        );
    });

    let name = format!("qkv_decode_small_r{r}");
    let xs1 = randt(vec![1, r]);
    b.bench(&format!("qkv_decode small r={r}"), || {
        black_box(
            rt.execute(
                &name,
                &[
                    xs1.clone(),
                    wq.clone(),
                    wk.clone(),
                    wv.clone(),
                    kc.clone(),
                    vc.clone(),
                    mask.clone(),
                ],
            )
            .unwrap(),
        );
    });
    println!("\ncached executables: {}", rt.cached());
}
