//! Storage-simulator benches: model evaluation cost for the access
//! patterns behind Fig 3/4 (the simulator itself must be cheap enough to
//! run figure sweeps), plus real-file thread-pool reads.

use neuron_chunking::benchlib::{black_box, header, Bencher};
use neuron_chunking::storage::{
    DeviceProfile, Extent, FlashDevice, RealFileDevice, SimulatedSsd,
};

fn main() {
    header("storage (simulator service-time model + real-file pool)");
    let mut b = Bencher::default();
    let dev = SimulatedSsd::timing_only(DeviceProfile::nano(), 1 << 40, 1);

    let scattered: Vec<Extent> = (0..4096)
        .map(|i| Extent::new(i as u64 * 16384, 7168))
        .collect();
    b.bench("sim service_time: 4096 scattered rows", || {
        black_box(dev.model_service_seconds(&scattered, 1.0));
    });

    let chunked: Vec<Extent> = (0..96)
        .map(|i| Extent::new(i as u64 * (1 << 20), 348 * 1024))
        .collect();
    b.bench("sim service_time: 96 saturating chunks", || {
        black_box(dev.model_service_seconds(&chunked, 1.0));
    });

    let mixed: Vec<Extent> = (0..2048)
        .map(|i| Extent::new(i as u64 * 65536, 4096 + (i % 13) * 4096))
        .collect();
    b.bench("sim service_time: 2048 mixed sizes (entropy path)", || {
        black_box(dev.model_service_seconds(&mixed, 1.0));
    });

    // Image-backed reads (the engine's weight-load path).
    let image = vec![0u8; 16 << 20];
    let imgdev = SimulatedSsd::with_image(DeviceProfile::nano(), image, 2);
    let extents: Vec<Extent> = (0..128)
        .map(|i| Extent::new(i as u64 * 65536, 3072))
        .collect();
    let mut out = vec![0u8; 128 * 3072];
    b.bench("sim read_batch: 128 x 3 KB rows into buffer", || {
        black_box(imgdev.read_batch(&extents, &mut out).unwrap());
    });

    // Real-file thread pool (page-cache-warm: upper bound on throughput).
    let path = std::env::temp_dir().join(format!("nc_bench_{}.img", std::process::id()));
    std::fs::write(&path, vec![7u8; 8 << 20]).unwrap();
    let real = RealFileDevice::open(&path, 6, false).unwrap();
    let extents: Vec<Extent> = (0..256)
        .map(|i| Extent::new(i as u64 * 16384, 8192))
        .collect();
    b.bench("real pread pool: 256 x 8 KB (warm cache)", || {
        black_box(real.service_time(&extents).unwrap());
    });
    std::fs::remove_file(path).ok();
}
