//! Minimal stand-in for the `anyhow` crate (the build environment has no
//! registry access). Implements the subset of the API this workspace uses:
//! [`Error`], [`Result`], [`Context`], and the `anyhow!` / `bail!` /
//! `ensure!` macros.
//!
//! Semantics mirror real `anyhow` where it matters:
//! * `Error` does **not** implement `std::error::Error`, which is what
//!   makes the blanket `From<E: std::error::Error>` conversion legal.
//! * `{:#}` (alternate) formatting prints the whole context chain
//!   outermost-first, `"outer: inner: root"`.

use std::fmt;

/// Boxed-string error with a context chain (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self {
            chain: vec![m.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// Root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>` with the error defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)+) => {
        $crate::Error::msg(format!($fmt, $($arg)+))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?;
        ensure!(v < 100, "value {v} too large");
        Ok(v)
    }

    #[test]
    fn question_mark_converts() {
        assert!(parse("12").is_ok());
        assert!(parse("nope").is_err());
        assert!(parse("200").is_err());
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "root").into();
        let e = e.context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("n = {}", n);
        assert_eq!(e.to_string(), "n = 3");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn ensure_bare_names_condition() {
        fn f(x: usize) -> Result<()> {
            ensure!(x > 0);
            Ok(())
        }
        let e = f(0).unwrap_err();
        assert!(e.to_string().contains("x > 0"));
    }
}
