//! Minimal stand-in for the `libc` crate (no registry access in the build
//! environment). Declares only what the crate uses: positional reads,
//! fadvise hints, the `O_DIRECT` flag, and `signal` for the serving
//! front end's graceful-shutdown handler.

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type c_void = std::ffi::c_void;
pub type size_t = usize;
pub type ssize_t = isize;
pub type off_t = i64;

/// `O_DIRECT` is architecture-specific on Linux.
#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "x86", target_arch = "riscv64")
))]
pub const O_DIRECT: c_int = 0o40000;
#[cfg(all(
    target_os = "linux",
    any(target_arch = "aarch64", target_arch = "arm", target_arch = "powerpc64")
))]
pub const O_DIRECT: c_int = 0o200000;
#[cfg(not(target_os = "linux"))]
pub const O_DIRECT: c_int = 0;

pub const POSIX_FADV_RANDOM: c_int = 1;
pub const POSIX_FADV_DONTNEED: c_int = 4;

/// `void (*)(int)` handler address, as `signal(2)` takes it.
pub type sighandler_t = usize;

pub const SIGINT: c_int = 2;
pub const SIGTERM: c_int = 15;

extern "C" {
    pub fn pread(fd: c_int, buf: *mut c_void, count: size_t, offset: off_t) -> ssize_t;
}

#[cfg(unix)]
extern "C" {
    pub fn signal(signum: c_int, handler: sighandler_t) -> sighandler_t;
}

/// No-op where `signal(2)` is unavailable: the server still shuts down
/// via `--duration` or process exit.
#[cfg(not(unix))]
pub unsafe fn signal(_signum: c_int, _handler: sighandler_t) -> sighandler_t {
    0
}

#[cfg(target_os = "linux")]
extern "C" {
    pub fn posix_fadvise(fd: c_int, offset: off_t, len: off_t, advice: c_int) -> c_int;
}

/// Page-cache advice is a best-effort hint; absent the syscall (non-Linux),
/// it is a no-op.
#[cfg(not(target_os = "linux"))]
pub unsafe fn posix_fadvise(_fd: c_int, _offset: off_t, _len: off_t, _advice: c_int) -> c_int {
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::unix::io::AsRawFd;

    #[test]
    fn pread_reads_at_offset() {
        let path = std::env::temp_dir().join(format!("libc_stub_test_{}", std::process::id()));
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(b"hello world").unwrap();
        drop(f);
        let f = std::fs::File::open(&path).unwrap();
        let mut buf = [0u8; 5];
        let rc = unsafe {
            pread(
                f.as_raw_fd(),
                buf.as_mut_ptr() as *mut c_void,
                5,
                6,
            )
        };
        assert_eq!(rc, 5);
        assert_eq!(&buf, b"world");
        std::fs::remove_file(path).ok();
    }
}
