//! Compile-time stand-in for the external `xla` crate (PJRT/XLA
//! bindings).
//!
//! The offline build environment has no registry access, but the `pjrt`
//! feature must keep *type-checking* so the gated backend cannot rot
//! silently (`cargo check --features pjrt` runs in CI). This stub
//! provides exactly the API surface `rust/src/runtime/pjrt.rs` uses;
//! every device-touching constructor returns an error at runtime. To
//! actually execute HLO artifacts, replace this path dependency with the
//! real `xla = "0.1.6"` crate in an environment with registry access.

use std::fmt;

/// Stub error: carries the operation name and a pointer at the real
/// crate.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: this build links the offline stub of the `xla` crate; \
         swap vendor/xla for the real crate to run the PJRT backend"
    ))
}

/// Host literal: shape + f32 payload.
#[derive(Clone, Debug, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over an f32 slice.
    pub fn vec1(data: &[f32]) -> Self {
        Self {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
        }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        Ok(Self {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Array shape of this literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape {
            dims: self.dims.clone(),
        })
    }

    /// Copy the payload out as a typed vector.
    pub fn to_vec<T: Clone>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Unpack a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Dimensions of an array-shaped literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// Open the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text file.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (module wrapper).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given input literals; returns per-device,
    /// per-output buffers.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device-resident result buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Fetch the buffer back to the host as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_round_trip() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let reshaped = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(reshaped.array_shape().unwrap().dims(), &[2, 3]);
    }

    #[test]
    fn device_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let err = Literal::default().to_tuple().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
