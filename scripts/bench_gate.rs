//! `bench-gate` — the CI perf-regression gate over `BENCH_e2e.json` and
//! `BENCH_serving.json` (the `redline` wire-level run file).
//!
//! Diffs the current bench report against the committed baseline
//! (`BENCH_baseline.json`) and fails (exit 1) when any matched entry's
//! `tokens_per_s` drops, or `p99_us`/`p999_us` rises, by more than the
//! threshold (default 15%, `NC_BENCH_GATE_PCT` or `--pct N` overrides).
//!
//! Usage:
//!   bench-gate CURRENT.json BASELINE.json [--pct N] [--relative] [--update]
//!
//! * `--update`  — refresh the baseline: copy CURRENT over BASELINE and
//!   exit 0. This is how the committed baseline is regenerated after an
//!   intentional perf change (run the bench, then
//!   `cargo run --release --bin bench-gate -- BENCH_e2e.json
//!   BENCH_baseline.json --update` and commit the result).
//! * `--relative` — machine-independent mode: instead of absolute
//!   tokens/s, each entry's current/baseline ratio is compared against
//!   the *median* ratio across all entries, so a uniformly slower (or
//!   faster) host cancels out and only configurations that regressed
//!   relative to the rest of the suite are flagged.
//!
//! Entries are matched on their identifying fields (mode, policy,
//! prefetch, threads, streams, devices, op, async_io, queue_depth, rps,
//! mix, slo — the last three identify served redline runs); entries present on
//! only one side are reported but never fail the gate (the bench matrix
//! is allowed to grow).
//!
//! The JSON is the flat machine-readable format `bench_e2e` emits; the
//! tiny parser below handles exactly that shape (one level of nesting,
//! string/number/bool scalars) — no external crates.

use std::collections::BTreeMap;
use std::process::ExitCode;

/// One parsed bench entry: identifying fields + metrics.
#[derive(Clone, Debug, Default)]
struct Entry {
    key: String,
    tokens_per_s: f64,
    p99_us: f64,
    p999_us: f64,
}

/// Split the fields of one flat JSON object body (no nested containers).
fn parse_object(body: &str) -> BTreeMap<String, String> {
    let mut fields = BTreeMap::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut prev_escape = false;
    let mut start = 0usize;
    let bytes = body.as_bytes();
    let mut parts: Vec<&str> = Vec::new();
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'"' if !prev_escape => in_str = !in_str,
            b'[' | b'{' if !in_str => depth += 1,
            b']' | b'}' if !in_str => depth = depth.saturating_sub(1),
            b',' if !in_str && depth == 0 => {
                parts.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
        prev_escape = b == b'\\' && !prev_escape;
    }
    if start < body.len() {
        parts.push(&body[start..]);
    }
    for part in parts {
        if let Some((k, v)) = part.split_once(':') {
            let key = k.trim().trim_matches('"').to_string();
            let val = v.trim().trim_matches('"').to_string();
            fields.insert(key, val);
        }
    }
    fields
}

/// Extract every measurement object (anything with a `tokens_per_s`
/// field) from a bench report.
fn parse_entries(json: &str) -> Vec<Entry> {
    // Keep in sync with `ID_FIELDS` in
    // `rust/src/serving/loadgen/compare.rs` (redline's compare applies
    // the same matching so local verdicts mirror the CI gate).
    const ID_FIELDS: [&str; 13] = [
        "mode",
        "policy",
        "prefetch",
        "threads",
        "streams",
        "devices",
        "op",
        "async_io",
        "queue_depth",
        "rps",
        "mix",
        "slo",
        "dtype",
    ];
    let mut entries = Vec::new();
    let bytes = json.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i] == b'{' && i > 0 {
            // Find the matching close brace (entries contain no nested
            // objects; strings contain no braces in this format).
            if let Some(rel) = json[i + 1..].find('}') {
                let body = &json[i + 1..i + 1 + rel];
                if body.contains("\"tokens_per_s\"") {
                    let fields = parse_object(body);
                    let key = ID_FIELDS
                        .iter()
                        .map(|f| fields.get(*f).cloned().unwrap_or_default())
                        .collect::<Vec<_>>()
                        .join("|");
                    entries.push(Entry {
                        key,
                        tokens_per_s: fields
                            .get("tokens_per_s")
                            .and_then(|v| v.parse().ok())
                            .unwrap_or(0.0),
                        p99_us: fields
                            .get("p99_us")
                            .and_then(|v| v.parse().ok())
                            .unwrap_or(0.0),
                        p999_us: fields
                            .get("p999_us")
                            .and_then(|v| v.parse().ok())
                            .unwrap_or(0.0),
                    });
                }
                i += rel + 1;
                continue;
            }
        }
        i += 1;
    }
    entries
}

fn median(mut v: Vec<f64>) -> f64 {
    if v.is_empty() {
        return 1.0;
    }
    v.sort_by(|a, b| a.total_cmp(b));
    v[v.len() / 2]
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // `--pct` consumes the following token as its value; every other
    // non-flag token is positional.
    let mut positional: Vec<&String> = Vec::new();
    let mut skip_value = false;
    for a in &args {
        if skip_value {
            skip_value = false;
            continue;
        }
        if a == "--pct" {
            skip_value = true;
            continue;
        }
        if !a.starts_with("--") {
            positional.push(a);
        }
    }
    if positional.len() != 2 {
        eprintln!("usage: bench-gate CURRENT.json BASELINE.json [--pct N] [--relative] [--update]");
        return ExitCode::from(2);
    }
    let (current_path, baseline_path) = (positional[0], positional[1]);
    let relative = args.iter().any(|a| a == "--relative");
    let update = args.iter().any(|a| a == "--update");
    let pct: f64 = args
        .iter()
        .position(|a| a == "--pct")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .or_else(|| {
            std::env::var("NC_BENCH_GATE_PCT")
                .ok()
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(15.0);

    if update {
        match std::fs::copy(current_path, baseline_path) {
            Ok(_) => {
                println!("baseline refreshed: {current_path} -> {baseline_path}");
                return ExitCode::SUCCESS;
            }
            Err(e) => {
                eprintln!("baseline refresh failed: {e}");
                return ExitCode::FAILURE;
            }
        }
    }

    let current = match std::fs::read_to_string(current_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot read {current_path}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let baseline = match std::fs::read_to_string(baseline_path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot read {baseline_path}: {e}");
            return ExitCode::FAILURE;
        }
    };
    let current = parse_entries(&current);
    let baseline = parse_entries(&baseline);
    if current.is_empty() || baseline.is_empty() {
        eprintln!(
            "no comparable entries (current: {}, baseline: {})",
            current.len(),
            baseline.len()
        );
        return ExitCode::FAILURE;
    }
    let by_key: BTreeMap<&str, &Entry> = current.iter().map(|e| (e.key.as_str(), e)).collect();

    // Pair up baseline entries with their current counterparts.
    let mut pairs: Vec<(&Entry, &Entry)> = Vec::new();
    let mut missing = 0usize;
    for base in &baseline {
        match by_key.get(base.key.as_str()) {
            Some(cur) => pairs.push((base, *cur)),
            None => {
                println!("  [skip] baseline-only entry: {}", base.key);
                missing += 1;
            }
        }
    }
    // A gate that matches nothing gates nothing: key-schema drift (e.g.
    // a new identity field) must fail loudly, not pass vacuously.
    if pairs.is_empty() {
        eprintln!(
            "perf gate FAILED: no baseline entry matches the current report \
             ({} baseline vs {} current entries) — the entry key schema drifted; \
             refresh the baseline with --update",
            baseline.len(),
            current.len()
        );
        return ExitCode::FAILURE;
    }
    let new_entries = current.len().saturating_sub(pairs.len());
    let ratio_median = median(
        pairs
            .iter()
            .filter(|(b, _)| b.tokens_per_s > 0.0)
            .map(|(b, c)| c.tokens_per_s / b.tokens_per_s)
            .collect(),
    );

    let floor = 1.0 - pct / 100.0;
    let ceil = 1.0 + pct / 100.0;
    let mut failures = 0usize;
    println!(
        "perf gate: {} matched entries, threshold {pct}% ({} mode, median speed ratio {:.3})",
        pairs.len(),
        if relative { "relative" } else { "absolute" },
        ratio_median
    );
    for (base, cur) in &pairs {
        if base.tokens_per_s <= 0.0 {
            continue;
        }
        let ratio = cur.tokens_per_s / base.tokens_per_s;
        let tput_bad = if relative {
            ratio < ratio_median * floor
        } else {
            ratio < floor
        };
        // Tail latency gates only in absolute mode (a latency percentile
        // has no meaningful cross-entry normalization).
        let tail_bad = |b: f64, c: f64| !relative && b > 0.0 && c > 0.0 && c / b > ceil;
        let p99_bad = tail_bad(base.p99_us, cur.p99_us);
        let p999_bad = tail_bad(base.p999_us, cur.p999_us);
        if tput_bad || p99_bad || p999_bad {
            failures += 1;
            println!(
                "  [FAIL] {}: tokens/s {:.1} -> {:.1} ({:+.1}%), p99 {:.1}us -> {:.1}us, \
                 p999 {:.1}us -> {:.1}us",
                base.key,
                base.tokens_per_s,
                cur.tokens_per_s,
                (ratio - 1.0) * 100.0,
                base.p99_us,
                cur.p99_us,
                base.p999_us,
                cur.p999_us
            );
        }
    }
    println!(
        "perf gate: {failures} regression(s), {missing} baseline-only, {new_entries} new \
         entries (new entries never gate; refresh the baseline with --update)"
    );
    if failures > 0 {
        eprintln!(
            "perf gate FAILED: >{pct}% regression vs {baseline_path}; if intentional, refresh \
             the baseline (see scripts/bench_gate.rs docs)"
        );
        return ExitCode::FAILURE;
    }
    println!("perf gate passed");
    ExitCode::SUCCESS
}
